"""Tier-scoped scenario episodes: preemption storms, tier outages, and
spot price spikes through the full continuous-clock adapt loop on a
tiered simulator plane.

The toy plane procures the same hardware on two tiers (on-demand and
spot) plus a slow on-demand type, so every tier event has real capacity
to hit and the engine's graceful-degradation fallback (over-provision the
surviving tiers when the spot pool evaporates mid-search) is reachable.
"""

import dataclasses

import numpy as np
import pytest

from repro.core.search_space import SearchSpace
from repro.scenario import ScenarioEngine, SimulatorPlane, build_episode
from repro.scenario.registry import EPISODES, composite
from repro.scenario.spec import EventSpec, PhaseSpec, ScenarioSpec
from repro.serving.instance import InstanceType, ModelProfile
from repro.serving.tiers import TierCatalog, tiered_variant
from repro.serving.workload import generate_workload

FAST = InstanceType("fast", price=1.0, flops=1e9, mem_bw=1e9, overhead=1e-3)
SLOW = InstanceType("slow", price=0.3, flops=2e8, mem_bw=5e8, overhead=2e-3)
PROF = ModelProfile("toy", flops_per_sample=1e6, act_bytes_per_sample=1e4,
                    weight_bytes=1e5, qos_latency=0.05)
TYPES = [FAST, tiered_variant(FAST, "spot"), SLOW]
BOUNDS = (3, 3, 2)
PRICES = tuple(t.price for t in TYPES)

N_EPISODES = 20
N_PER_PHASE = 90
WINDOW = 30


def _plane(spec):
    wls = {d: generate_workload(spec.seed, spec.n_base_queries, 100.0,
                                batch_dist=d, median_batch=8.0,
                                mean_batch=10.0, std_batch=4.0, max_batch=32)
           for d in spec.batch_dists}
    return SimulatorPlane(PROF, TYPES, wls, max_instances=8,
                          catalog=TierCatalog(TYPES))


def _run(spec, carry=True, warm_scoring=None):
    return ScenarioEngine(spec, _plane(spec),
                          SearchSpace(bounds=BOUNDS, prices=PRICES),
                          carry_queue_state=carry,
                          warm_candidate_scoring=warm_scoring).run()


def _trim(spec):
    return dataclasses.replace(spec, init_budget=20, rescale_budget=10,
                               recover_budget=10)


def test_tiered_plane_exposes_tier_surface():
    spec = ScenarioSpec(name="t", phases=(PhaseSpec("a", 60),), window=30)
    plane = _plane(spec)
    assert plane.type_tiers == ("on_demand", "spot", "on_demand")
    assert plane.cold_starts is not None
    assert plane.cost_penalties is not None
    # the spot copy of the same hardware carries the larger risk premium
    assert plane.cost_penalties[1] > plane.cost_penalties[0]


def test_tier_episodes_registered():
    for name in ("spot-storm", "tier-outage"):
        assert name in EPISODES
        spec = build_episode(name, n=120, window=40, seed=5)
        assert spec.validate() is spec
        assert spec == build_episode(name, n=120, window=40, seed=5)
        assert any(e.tier == "spot" for e in spec.events)
    storm = build_episode("spot-storm", n=120, window=40, seed=5)
    assert any(e.kind == "preemption_storm" for e in storm.events)
    # hazard timelines vary with the seed
    assert (build_episode("spot-storm", n=120, window=40, seed=6).events
            != storm.events)


def test_tier_outage_zeroes_spot_until_restock():
    """From the outage cut to the next phase boundary no window may run
    spot capacity; the boundary restock brings the tier's bounds back."""
    spec = _trim(ScenarioSpec(
        name="outage", qos_target=0.9, window=WINDOW,
        provision_queries=WINDOW,
        phases=(PhaseSpec("steady", N_PER_PHASE),
                PhaseSpec("outage", N_PER_PHASE),
                PhaseSpec("restored", N_PER_PHASE)),
        events=(EventSpec("tier_outage", phase=1, at_frac=0.34,
                          tier="spot"),)))
    rep = _run(spec)
    outage = [e for e in rep.events if e.kind == "tier_outage"]
    assert len(outage) == 1 and "type 1" in outage[0].detail
    at = outage[0].at_query
    for w in rep.windows:
        if at <= w.start < 2 * N_PER_PHASE:
            assert w.config[1] == 0, (w.start, w.config)
    kinds = [a.kind for a in rep.actions]
    assert "recover_outage" in kinds
    assert "restock" in kinds                   # the market returns the tier
    assert rep.recovered_all_events


def test_land_pending_stages_union_then_pure_removal():
    """A booked restock trim lands in two stages: the union pool first
    (additions wake cold beside the warm incumbents), then a pure-removal
    switch to the trim target booked for when the additions are warm."""
    spec = ScenarioSpec(name="t", phases=(PhaseSpec("a", 60),), window=30)
    eng = ScenarioEngine(spec, _plane(spec),
                         SearchSpace(bounds=BOUNDS, prices=PRICES))
    eng._pending_switch = (10, (2, 1, 0))
    eng._pending_trim = (0, 1, 0)
    config = eng._land_pending((1, 0, 0), 10, 1.0)
    assert config == (2, 1, 0)                   # union stage deployed
    at, target = eng._pending_switch
    assert target == (0, 1, 0)                   # removal stage booked
    assert at > 10                               # ... for after the warm-up
    assert eng._pending_trim is None
    # landing the removal stage books nothing further
    config = eng._land_pending(config, at, 1.0)
    assert config == (0, 1, 0)
    assert eng._pending_switch is None


def test_restock_trim_returns_to_pre_storm_pool():
    """Any restock trim must walk the portfolio back to a strictly cheaper
    pool that actually served before the capacity loss."""
    spec = _trim(ScenarioSpec(
        name="outage-trim", qos_target=0.9, window=WINDOW,
        provision_queries=WINDOW,
        phases=(PhaseSpec("steady", N_PER_PHASE),
                PhaseSpec("outage", N_PER_PHASE),
                PhaseSpec("restored", 2 * N_PER_PHASE)),
        events=(EventSpec("tier_outage", phase=1, at_frac=0.34,
                          tier="spot"),)))
    rep = _run(spec)
    assert rep.recovered_all_events
    served = {tuple(w.config) for w in rep.windows}
    for a in rep.actions:
        if a.kind != "restock_trim":
            continue
        assert a.new_price < a.old_price
        assert tuple(a.new_config) in served


def test_preemption_storm_kills_deployed_fraction_and_restocks():
    spec = _trim(ScenarioSpec(
        name="storm", qos_target=0.9, window=WINDOW,
        provision_queries=WINDOW,
        phases=(PhaseSpec("calm", N_PER_PHASE),
                PhaseSpec("storm", N_PER_PHASE),
                PhaseSpec("after", N_PER_PHASE)),
        events=(EventSpec("preemption_storm", phase=1, at_frac=0.3,
                          tier="spot", factor=1.0),)))
    rep = _run(spec)
    storm = [e for e in rep.events if e.kind == "preemption_storm"]
    assert len(storm) == 1
    assert storm[0].detail.startswith("spot storm kill 1:")
    if "no capacity deployed" not in storm[0].detail:
        assert [a.kind for a in rep.actions].count("recover_storm") == 1
        assert any(a.kind == "restock" for a in rep.actions)
    assert rep.recovered_all_events
    assert np.isfinite(rep.carried_wait_total)


def test_price_spike_reprices_every_spot_type():
    spec = _trim(ScenarioSpec(
        name="spike", qos_target=0.9, window=WINDOW,
        phases=(PhaseSpec("a", N_PER_PHASE), PhaseSpec("b", N_PER_PHASE)),
        events=(EventSpec("price_spike", phase=0, at_frac=0.4, tier="spot",
                          factor=1.5),)))
    rep = _run(spec)
    spikes = [a for a in rep.actions if a.kind == "reprice"]
    assert len(spikes) == 1
    # windows after the spike bill the spot type 1.5x
    post = [w for w in rep.windows
            if w.start >= spikes[0].at_query and w.config[1] > 0]
    for w in post:
        expect = (w.config[0] * PRICES[0] + w.config[1] * PRICES[1] * 1.5
                  + w.config[2] * PRICES[2])
        assert w.price == pytest.approx(expect)
    assert rep.recovered_all_events


def test_tier_events_are_noops_on_untiered_planes():
    """A spot storm against a plane with no spot types must not touch the
    pool — and must still count as recovered."""
    spec = _trim(ScenarioSpec(
        name="noop", qos_target=0.9, window=WINDOW,
        phases=(PhaseSpec("a", N_PER_PHASE), PhaseSpec("b", N_PER_PHASE)),
        events=(EventSpec("preemption_storm", phase=0, at_frac=0.3,
                          tier="serverless", factor=0.9),
                EventSpec("price_spike", phase=0, at_frac=0.5,
                          tier="serverless", factor=2.0),)))
    rep = _run(spec)
    assert rep.recovered_all_events
    assert not any(a.kind in ("recover_storm", "reprice")
                   for a in rep.actions)
    assert all("no capacity" in e.detail or "price" in e.detail
               for e in rep.events)


def test_tiered_composite_fuzz_recovers_every_seed():
    """The seeded tiered fuzz sweep: N_EPISODES timelines drawn from the
    full registry (storms, outages, spikes included), each run with the
    carried clock + warm scoring — every event must recover, the backlog
    accounting stays finite, and windows cover every query exactly once."""
    for seed in range(N_EPISODES):
        spec = _trim(composite(n=N_PER_PHASE, window=WINDOW, seed=seed,
                               qos_target=0.9, n_events=3, tiered=True))
        rep = _run(spec)
        ctx = (seed, [(e.kind, e.phase) for e in rep.events])
        assert rep.recovered_all_events, ctx
        assert np.isfinite(rep.carried_wait_total), ctx
        assert rep.carried_wait_total >= 0.0, ctx
        n_total = sum(ph.n_queries for ph in spec.phases)
        assert sum(w.end - w.start for w in rep.windows) == n_total, ctx
        deltas = [a.warm_idle_delta for a in rep.actions]
        assert all(d is None or np.isfinite(d) for d in deltas), ctx
