"""SearchSpace lattice enumeration, costs, index round-trips."""

import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core.search_space import SearchSpace, estimate_upper_bounds


def test_enumeration_shape_and_order():
    sp = SearchSpace(bounds=(2, 1), prices=(1.0, 2.0))
    lat = sp.enumerate()
    assert lat.shape == (6, 2)
    # increasing order within each dimension (paper's smoothness arrangement)
    np.testing.assert_array_equal(
        lat, [[0, 0], [0, 1], [1, 0], [1, 1], [2, 0], [2, 1]])


@given(st.tuples(st.integers(0, 5), st.integers(0, 4), st.integers(0, 3)))
@settings(max_examples=60, deadline=None)
def test_index_roundtrip(cfg):
    sp = SearchSpace(bounds=(5, 4, 3), prices=(1.0, 1.0, 1.0))
    lat = sp.enumerate()
    idx = sp.index_of(cfg)
    assert tuple(lat[idx]) == cfg


def test_costs_and_max_cost():
    sp = SearchSpace(bounds=(2, 3), prices=(0.5, 0.25))
    assert sp.max_cost == pytest.approx(2 * 0.5 + 3 * 0.25)
    lat = sp.enumerate()
    np.testing.assert_allclose(sp.costs(lat), lat @ np.array([0.5, 0.25]))


def test_invalid_args():
    with pytest.raises(ValueError):
        SearchSpace(bounds=(1,), prices=(1.0, 2.0))
    with pytest.raises(ValueError):
        SearchSpace(bounds=(-1,), prices=(1.0,))
    with pytest.raises(ValueError):
        SearchSpace(bounds=(2,), prices=(0.0,))
    sp = SearchSpace(bounds=(2, 2), prices=(1.0, 1.0))
    with pytest.raises(ValueError):
        sp.index_of((3, 0))


def test_estimate_upper_bounds_saturation():
    """m_i is the count at which the QoS rate saturates (paper §4)."""
    def oracle(config):
        # type 0 saturates at 3 instances, type 1 at 5
        caps = (3, 5)
        rates = [min(c, cap) / cap for c, cap in zip(config, caps) if c > 0]
        return rates[0] if rates else 0.0
    bounds = estimate_upper_bounds(oracle, 2, hard_cap=10)
    assert bounds == (3, 5)
