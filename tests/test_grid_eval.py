"""Joint (workload × config) grid engine + device-side pruning + rescale.

Three equivalence contracts of the PR:

* grid bit-identity — every cell of the ``simulate``/``qos`` grid lanes
  equals the single-config path bound to the scaled workload, bit for bit;
* device-side prune masks — the fused on-device tell update
  (``pruning.apply_prune_rules``) stays bit-identical to the host-side
  ``PruneSet`` + sampled mirrors over whole recorded BO runs;
* grid-driven ``rescale`` — the autoscaler-in-the-loop search lands on a
  configuration that is genuinely feasible under the scaled load.
"""

import numpy as np
import pytest

from repro.core import RibbonOptimizer, select_batch
from repro.core.search_space import SearchSpace
from repro.serving.autoscaler import rescale
from repro.serving.instance import (InstanceType, ModelProfile,
                                    service_time_table)
from repro.serving.pool import PoolEvaluator
from repro.serving.simulator import PoolSimulator, _qos_threshold_f32
from repro.serving.workload import generate_workload

FAST = InstanceType("fast", price=1.0, flops=1e9, mem_bw=1e9, overhead=1e-3)
SLOW = InstanceType("slow", price=0.3, flops=2e8, mem_bw=5e8, overhead=2e-3)
PROF = ModelProfile("toy", flops_per_sample=1e6, act_bytes_per_sample=1e4,
                    weight_bytes=1e5, qos_latency=0.05)

MAX_INST = 8
FACTORS = (1.0, 1.2, 1.5, 2.0)


def _workload(seed=0, n=200, rate=120.0):
    return generate_workload(seed, n, rate, median_batch=8.0, max_batch=32)


def _sim(wl=None):
    wl = wl or _workload()
    return PoolSimulator(PROF, [FAST, SLOW], wl, max_instances=MAX_INST)


def _scaled_sim(wl, factor):
    return PoolSimulator(PROF, [FAST, SLOW], wl.scaled(factor),
                         max_instances=MAX_INST)


def _configs(n=8, seed=0):
    rng = np.random.default_rng(seed)
    cfgs = rng.integers(0, 5, size=(n, 2))
    cfgs[0] = (0, 0)                              # empty pool
    cfgs[1] = (MAX_INST // 2, MAX_INST // 2)      # max-capacity padding
    return cfgs


# ----------------------------------------------------------- grid bit-identity
def test_latencies_grid_matches_scaled_single_exactly():
    """simulate(..., workloads=)[w, b] == the single lane of a simulator
    bound to workload.scaled(factor_w), bit for bit (4 x 8 grid)."""
    wl = _workload()
    sim = _sim(wl)
    cfgs = _configs()
    grid = sim.simulate(cfgs, workloads=FACTORS).lat
    assert grid.shape == (len(FACTORS), len(cfgs), wl.n_queries)
    for w, f in enumerate(FACTORS):
        scaled = _scaled_sim(wl, f)
        for b, cfg in enumerate(cfgs):
            single = scaled.simulate(tuple(int(c) for c in cfg)).lat
            np.testing.assert_array_equal(grid[w, b], single)


def test_qos_rate_grid_matches_scaled_single_exactly():
    """The acceptance grid: qos(...).rates[w, b] == the single rate of
    (workload_w, config_b) elementwise over a 4 x 8 grid."""
    wl = _workload(seed=3, n=150, rate=200.0)
    sim = _sim(wl)
    cfgs = _configs(seed=1)
    rates = sim.qos(cfgs, workloads=FACTORS).rates
    assert rates.shape == (len(FACTORS), len(cfgs))
    for w, f in enumerate(FACTORS):
        scaled = _scaled_sim(wl, f)
        for b, cfg in enumerate(cfgs):
            assert rates[w, b] == float(
                scaled.qos(tuple(int(c) for c in cfg)).rates)


def test_qos_rate_grid_matches_batch_rows():
    """Row w of the grid == the batch lane on the scaled simulator."""
    wl = _workload(seed=5)
    sim = _sim(wl)
    cfgs = _configs(seed=2)
    rates = sim.qos(cfgs, workloads=FACTORS).rates
    for w, f in enumerate(FACTORS):
        np.testing.assert_array_equal(
            rates[w], _scaled_sim(wl, f).qos(cfgs).rates)


def test_grid_unit_factor_row_matches_unscaled_paths():
    sim = _sim()
    cfgs = _configs(seed=4)
    rates = sim.qos(cfgs, workloads=(1.0,)).rates
    np.testing.assert_array_equal(rates[0], sim.qos(cfgs).rates)
    lat = sim.simulate(cfgs, workloads=(1.0,)).lat
    np.testing.assert_array_equal(lat[0], sim.simulate(cfgs).lat)


def test_grid_empty_and_zero_configs():
    sim = _sim()
    empty = sim.simulate(np.zeros((0, 2), dtype=np.int64),
                         workloads=FACTORS).lat
    assert empty.shape == (len(FACTORS), 0, sim.workload.n_queries)
    assert sim.qos(np.zeros((0, 2), dtype=np.int64),
                   workloads=FACTORS).rates.shape == (len(FACTORS), 0)
    # the all-zero config row: +inf latencies, zero satisfaction
    grid = sim.simulate([(0, 0)], workloads=FACTORS).lat
    assert np.isinf(grid).all()
    assert (sim.qos([(0, 0)], workloads=FACTORS).rates == 0.0).all()


def test_grid_rejects_bad_load_factors():
    sim = _sim()
    with pytest.raises(ValueError):
        sim.qos([(1, 1)], workloads=[])
    with pytest.raises(ValueError):
        sim.qos([(1, 1)], workloads=[0.0])
    with pytest.raises(ValueError):
        sim.qos([(1, 1)], workloads=[-1.5])
    with pytest.raises(ValueError):
        sim.simulate([(1, 1)], workloads=[np.inf])


def test_grid_arr_shards_pads_cyclically_beyond_workload_count():
    """The workload-axis pad may exceed W (one load level on an 8-device
    host): rows must wrap cyclically instead of silently under-filling the
    device multiple.  shard_map takes global operands, so the cached array
    keeps its 2-D shape — padded to a device multiple and laid out over the
    lane mesh."""
    sim = _sim()
    for n_w, n_dev in [(1, 4), (2, 8), (3, 4), (5, 8), (4, 4)]:
        factors = tuple(1.0 + 0.1 * i for i in range(n_w))
        arr = np.asarray(sim._stacked_arrivals(factors), np.float32)
        out = np.asarray(sim._grid_arr_shards(arr, "w", n_dev, factors))
        pad_w = (-n_w) % n_dev
        assert out.shape == (n_w + pad_w, sim.workload.n_queries)
        for i in range(n_w + pad_w):
            np.testing.assert_array_equal(out[i], arr[i % n_w])


@pytest.mark.slow
def test_grid_bit_identity_under_forced_multi_device(tmp_path):
    """the grid qos lane must survive (and stay exact on) hosts where
    benchmarks/__init__.py forces many XLA host devices — including the
    W=1, odd-B case whose workload-axis pad exceeds W."""
    import os
    import subprocess
    import sys
    script = tmp_path / "grid_multidev.py"
    script.write_text(
        "import numpy as np\n"
        "from repro.serving.simulator import PoolSimulator\n"
        "from repro.serving.instance import InstanceType, ModelProfile\n"
        "from repro.serving.workload import generate_workload\n"
        "import jax\n"
        "assert jax.local_device_count() == 4\n"
        "fast = InstanceType('fast', price=1.0, flops=1e9, mem_bw=1e9,\n"
        "                    overhead=1e-3)\n"
        "slow = InstanceType('slow', price=0.3, flops=2e8, mem_bw=5e8,\n"
        "                    overhead=2e-3)\n"
        "prof = ModelProfile('toy', flops_per_sample=1e6,\n"
        "                    act_bytes_per_sample=1e4, weight_bytes=1e5,\n"
        "                    qos_latency=0.05)\n"
        "wl = generate_workload(0, 100, 120.0, median_batch=8.0,\n"
        "                       max_batch=32)\n"
        "sim = PoolSimulator(prof, [fast, slow], wl, max_instances=8)\n"
        "cfgs = np.array([[1, 0], [2, 1], [0, 3]])  # odd B\n"
        "for factors in [(1.5,), (1.0, 1.2), (1.0, 1.2, 1.5)]:\n"
        "    got = sim.qos(cfgs, workloads=factors).rates\n"
        "    for w, f in enumerate(factors):\n"
        "        ref = PoolSimulator(prof, [fast, slow], wl.scaled(f),\n"
        "                            max_instances=8).qos(cfgs).rates\n"
        "        np.testing.assert_array_equal(got[w], ref)\n"
        "print('MULTIDEV-OK')\n")
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    env["PYTHONPATH"] = "src"
    proc = subprocess.run([sys.executable, str(script)], env=env,
                          capture_output=True, text=True, timeout=300,
                          cwd=str(__import__("pathlib").Path(
                              __file__).resolve().parent.parent))
    assert proc.returncode == 0, proc.stderr
    assert "MULTIDEV-OK" in proc.stdout


def test_grid_stacked_service_tables_match_per_dist_sims():
    """The per-workload service-table axis: row w of the grid with stacked
    tables equals a simulator bound to that row's batch stream (same
    arrivals, different batches), bit for bit, on both grid paths."""
    wl_ln = _workload(seed=2, n=150, rate=150.0)
    wl_ga = generate_workload(2, 150, 150.0, batch_dist="gaussian",
                              mean_batch=10.0, std_batch=4.0, max_batch=32)
    np.testing.assert_array_equal(wl_ln.arrivals, wl_ga.arrivals)
    sim = _sim(wl_ln)
    cfgs = _configs(seed=6)
    tables = np.stack([
        service_time_table(PROF, [FAST, SLOW], wl_ln.batches),
        service_time_table(PROF, [FAST, SLOW], wl_ga.batches)])
    factors = (1.0, 1.5)
    rates = sim.qos(cfgs, workloads=factors, service_tables=tables).rates
    lat = sim.simulate(cfgs, workloads=factors,
                       service_tables=tables).lat
    for w, (f, wl) in enumerate(zip(factors, (wl_ln, wl_ga))):
        ref = PoolSimulator(PROF, [FAST, SLOW], wl.scaled(f),
                            max_instances=MAX_INST)
        np.testing.assert_array_equal(rates[w], ref.qos(cfgs).rates)
        np.testing.assert_array_equal(lat[w], ref.simulate(cfgs).lat)


def test_grid_stacked_service_tables_shape_validated():
    sim = _sim()
    nq = sim.workload.n_queries
    with pytest.raises(ValueError):        # W mismatch
        sim.qos([(1, 1)], workloads=(1.0, 1.5),
                service_tables=np.zeros((1, 2, nq)))
    with pytest.raises(ValueError):        # type-axis mismatch
        sim.simulate([(1, 1)], workloads=(1.0,),
                     service_tables=np.zeros((1, 3, nq)))
    with pytest.raises(ValueError):        # query-axis mismatch
        sim.qos([(1, 1)], workloads=(1.0,),
                service_tables=np.zeros((1, 2, nq - 1)))


def test_latencies_waits_consistent_with_latencies():
    sim = _sim()
    for cfg in [(2, 1), (1, 0)]:
        r = sim.simulate(cfg)
        lat, waits = r.lat, r.waits
        np.testing.assert_array_equal(lat, sim.simulate(cfg).lat)
        assert (waits >= 0).all()
        assert np.isfinite(waits).all()
        assert (waits <= lat).all()        # wait is part of the latency
    r0 = sim.simulate((0, 0))
    lat, waits = r0.lat, r0.waits
    assert np.isinf(lat).all() and np.isinf(waits).all()


def test_qos_threshold_f32_admits_same_latency_set():
    """The rounded-down float32 target classifies every float32 latency
    exactly as the float64 host comparison does."""
    for qos in (0.02, 0.03, 0.04, 0.4, 0.8, 0.05):
        t = _qos_threshold_f32(qos)
        probes = np.array([qos, t], dtype=np.float32)
        probes = np.concatenate([probes,
                                 np.nextafter(probes, np.float32(np.inf)),
                                 np.nextafter(probes, np.float32(-np.inf))])
        for x in probes:
            assert (float(x) <= qos) == (x <= np.float32(t))


# ------------------------------------------------------------- evaluator grid
def test_evaluator_grid_consistent_with_call_and_memoized():
    ev = PoolEvaluator(PROF, [FAST, SLOW], _workload(n=150, rate=150.0),
                       max_instances=MAX_INST)
    cfgs = [(1, 0), (2, 1), (0, 3), (1, 0)]       # includes a duplicate
    rates = ev.grid(cfgs, FACTORS)
    assert rates.shape == (len(FACTORS), len(cfgs))
    np.testing.assert_array_equal(rates[:, 0], rates[:, 3])
    n_after_grid = ev.n_evals
    assert n_after_grid == 3 * len(FACTORS)       # distinct cells only
    # unit-factor row shares the plain memo: no new evaluations
    for cfg, rate in zip(cfgs, rates[0]):
        assert rate == ev(cfg)
    assert ev.n_evals == n_after_grid
    # repeat grid: fully cached
    np.testing.assert_array_equal(ev.grid(cfgs, FACTORS), rates)
    assert ev.n_evals == n_after_grid
    # a subset at a subset of factors: still fully cached
    sub = ev.grid(cfgs[:2], FACTORS[1:3])
    np.testing.assert_array_equal(sub, rates[1:3, :2])
    assert ev.n_evals == n_after_grid


def test_evaluator_grid_matches_scaled_evaluator():
    wl = _workload(seed=7, n=150, rate=150.0)
    ev = PoolEvaluator(PROF, [FAST, SLOW], wl, max_instances=MAX_INST)
    hot = PoolEvaluator(PROF, [FAST, SLOW], wl.scaled(1.5),
                        max_instances=MAX_INST)
    cfgs = [(2, 0), (1, 2), (3, 3)]
    rates = ev.grid(cfgs, [1.5])[0]
    for cfg, rate in zip(cfgs, rates):
        assert rate == hot(cfg)


# -------------------------------------------------------- device-side pruning
SPACE = SearchSpace(bounds=(6, 8), prices=(1.0, 0.35))


def _oracle(config):
    cap = float(np.dot((10.0, 3.0), np.asarray(config, dtype=np.float64)))
    return min(1.0, cap / 33.0)


def _assert_masks_equal(opt):
    np.testing.assert_array_equal(np.asarray(opt._blocked_dev),
                                  opt.sampled | opt.prune.mask)


def test_device_mask_tracks_host_pruneset_over_bo_run():
    """Over a recorded BO run, the device-resident blocked mask stays
    bit-identical to the host PruneSet|sampled after every tell (both prune
    rules fire along the way: feasible incumbents and >θ violators)."""
    opt = RibbonOptimizer(SPACE, qos_target=0.99)
    fired = {"down": False, "cost": False}
    for _ in range(20):
        cfg = opt.ask()
        if cfg is None:
            break
        rate = _oracle(cfg)
        fired["cost" if rate >= 0.99 else "down"] = True
        opt.tell(cfg, rate)
        _assert_masks_equal(opt)
    assert fired["cost"] and fired["down"]


def test_device_mask_tracks_host_after_warm_restart():
    opt = RibbonOptimizer(SPACE, qos_target=0.99)
    for _ in range(8):
        cfg = opt.ask()
        opt.tell(cfg, _oracle(cfg))
    opt.warm_restart(new_qos_of_best=0.7)
    _assert_masks_equal(opt)
    for _ in range(5):
        cfg = opt.ask()
        if cfg is None:
            break
        opt.tell(cfg, 0.8 * _oracle(cfg))
        _assert_masks_equal(opt)


def test_device_mask_rebuilt_on_state_restore():
    opt = RibbonOptimizer(SPACE, qos_target=0.99)
    for _ in range(6):
        cfg = opt.ask()
        opt.tell(cfg, _oracle(cfg))
    state = opt.state_dict()
    fresh = RibbonOptimizer(SPACE, qos_target=0.99)
    fresh.load_state_dict(state)
    _assert_masks_equal(fresh)
    assert fresh.ask() == opt.ask()


def test_select_batch_returns_updated_mask():
    """select_batch takes the device mask and returns it with the q picks
    marked — a strict superset of the input mask."""
    opt = RibbonOptimizer(SPACE, qos_target=0.99)
    for _ in range(4):
        cfg = opt.ask()
        opt.tell(cfg, _oracle(cfg))
    x, y, mask = opt.gp.buffers()
    blocked_in = opt._blocked_dev
    picks, scores, blocked_out = select_batch(
        x, y, mask, opt._lattice_dev, opt.gp.denom,
        float(opt.best_objective_observed()), blocked_in,
        opt._weights_dev, 4)
    picks = np.asarray(picks)
    b_in, b_out = np.asarray(blocked_in), np.asarray(blocked_out)
    assert b_out[picks].all()
    assert (b_out | b_in).sum() == b_out.sum()     # superset
    assert b_out.sum() == b_in.sum() + len(set(picks.tolist()))
    # taking-and-returning leaves the optimizer's own mask untouched (ask
    # stays idempotent until the matching tells arrive)
    assert opt.ask_batch(3) == opt.ask_batch(3)
    _assert_masks_equal(opt)


# ------------------------------------------------------------ rescale on grid
def test_rescale_grid_integration():
    """rescale with load_factors drives the grid path end-to-end: the new
    optimum is feasible under the scaled load, and qos_by_load reports every
    monitored level from cache."""
    wl = _workload(seed=0, n=200, rate=120.0)
    ev = PoolEvaluator(PROF, [FAST, SLOW], wl, max_instances=MAX_INST)
    space = SearchSpace(bounds=(4, 4), prices=(1.0, 0.3))
    opt = RibbonOptimizer(space, qos_target=0.9)
    for _ in range(25):
        cfg = opt.ask()
        if cfg is None or opt.done:
            break
        opt.tell(cfg, ev(cfg))
    assert opt.best_config is not None

    n_before = ev.n_evals
    event = rescale(opt, ev, budget=25, load_factors=(1.0, 1.5))
    assert event.new_best is not None
    assert event.qos_by_load is not None
    assert set(event.qos_by_load) == {1.0, 1.5}
    # the reported winner is genuinely feasible under the scaled workload
    hot = PoolEvaluator(PROF, [FAST, SLOW], wl.scaled(1.5),
                        max_instances=MAX_INST)
    assert hot(event.new_best) >= 0.9
    assert event.qos_by_load[1.5] == hot(event.new_best)
    assert ev.n_evals > n_before


def test_rescale_legacy_callable_path_unchanged():
    space = SearchSpace(bounds=(5, 8), prices=(1.0, 0.3))

    def oracle(cfg, demand=31.0 * 1.5):
        return min(1.0, float(np.dot((10.0, 3.0),
                                     np.asarray(cfg, float))) / demand)

    opt = RibbonOptimizer(space, qos_target=0.99)
    for _ in range(20):
        cfg = opt.ask()
        if cfg is None or opt.done:
            break
        opt.tell(cfg, min(1.0, oracle(cfg) * 1.5))
    event = rescale(opt, oracle, budget=30)
    assert event.new_best is not None
    assert event.qos_by_load is None
    assert oracle(event.new_best) >= 0.99


def test_rescale_grid_requires_grid_evaluator():
    space = SearchSpace(bounds=(3, 3), prices=(1.0, 0.3))
    opt = RibbonOptimizer(space, qos_target=0.9)
    for _ in range(5):
        cfg = opt.ask()
        opt.tell(cfg, _oracle(cfg))
    with pytest.raises(TypeError):
        rescale(opt, _oracle, budget=5, load_factors=(1.0, 1.5))


# ------------------------------------------------------- edge cases + caches
def test_grid_edges_zero_pool_rows_and_single_query_no_nan():
    """Zero-pool config rows and single-query streams flow through the grid
    and waits paths without NaN."""
    wl = _workload(n=1, rate=50.0)
    sim = PoolSimulator(PROF, [FAST, SLOW], wl, max_instances=MAX_INST)
    cfgs = [(0, 0), (1, 0), (0, 2)]
    rates = sim.qos(cfgs, workloads=(1.0, 2.0)).rates
    assert rates.shape == (2, 3)
    assert not np.isnan(rates).any()
    assert (rates[:, 0] == 0.0).all()          # empty pool: all violations
    lat = sim.simulate(cfgs, workloads=(1.0, 2.0)).lat
    assert np.isinf(lat[:, 0]).all()
    assert np.isfinite(lat[:, 1:]).all()
    r1 = sim.simulate((1, 0))
    lat1, waits1 = r1.lat, r1.waits
    assert lat1.shape == waits1.shape == (1,)
    assert np.isfinite(lat1).all() and waits1[0] == 0.0
    # warm start over a single-query segment
    rw = sim.simulate((1, 0), state=sim.initial_state())
    np.testing.assert_array_equal(rw.lat, lat1)
    assert np.isfinite(rw.state.free[:1]).all()


def test_grid_arr_shard_cache_is_lru_with_hit_refresh():
    """The per-load-factor-tuple device cache of arrival grids evicts the
    least *recently used* entry: re-sweeping one level set keeps it resident
    while fresh sets cycle through."""
    sim = _sim()
    arr = np.asarray(sim.workload.arrivals, np.float32)[None, :]
    hot = ("b", 2, (1.0,))
    sim._grid_arr_shards(arr, "b", 2, (1.0,))
    for k in range(7):                          # fill the 8-entry cache
        sim._grid_arr_shards(arr, "b", 2, (1.0 + 0.1 * (k + 1),))
    assert hot in sim._grid_arrs and len(sim._grid_arrs) == 8
    sim._grid_arr_shards(arr, "b", 2, (1.0,))   # hit: refresh recency
    sim._grid_arr_shards(arr, "b", 2, (9.9,))   # miss: evicts the LRU entry
    assert hot in sim._grid_arrs                # survived thanks to the hit
    assert ("b", 2, (1.1,)) not in sim._grid_arrs   # the stalest went
    assert len(sim._grid_arrs) == 8
