"""FCFS pool simulator: invariants + equivalence with a pure-python oracle,
plus the continuous-time warm-start contracts (PoolState carry)."""

import numpy as np
import pytest

from repro.serving.instance import InstanceType, ModelProfile
from repro.serving.simulator import (PoolSimulator, PoolState,
                                     _MAX_HORIZON)
from repro.serving.workload import Workload, generate_workload

FAST = InstanceType("fast", price=1.0, flops=1e9, mem_bw=1e9, overhead=1e-3)
SLOW = InstanceType("slow", price=0.3, flops=2e8, mem_bw=5e8, overhead=2e-3)
PROF = ModelProfile("toy", flops_per_sample=1e6, act_bytes_per_sample=1e4,
                    weight_bytes=1e5, qos_latency=0.05)


def _wl(seed=0, n=200, rate=120.0):
    return generate_workload(seed, n, rate, median_batch=8.0, max_batch=32)


def python_fcfs_oracle(workload: Workload, types, counts, profile):
    """Straightforward FCFS reference: first idle instance in type order,
    else earliest-freeing instance."""
    slots = []
    for t_idx, c in enumerate(counts):
        slots += [t_idx] * c
    free = [0.0] * len(slots)
    lat = []
    for arr, b in zip(workload.arrivals, workload.batches):
        idle = [i for i, f in enumerate(free) if f <= arr]
        pick = idle[0] if idle else int(np.argmin(free))
        start = max(arr, free[pick])
        svc = float(types[slots[pick]].latency(profile, b))
        free[pick] = start + svc
        lat.append(free[pick] - arr)
    return np.array(lat)


@pytest.mark.parametrize("counts", [(1, 0), (2, 0), (1, 2), (3, 3), (0, 2)])
def test_scan_matches_python_oracle(counts):
    wl = _wl()
    sim = PoolSimulator(PROF, [FAST, SLOW], wl, max_instances=8)
    got = sim.simulate(counts).lat
    want = python_fcfs_oracle(wl, [FAST, SLOW], counts, PROF)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-6)


def test_latency_at_least_service_time():
    wl = _wl()
    sim = PoolSimulator(PROF, [FAST, SLOW], wl, max_instances=8)
    lat = sim.simulate((2, 1)).lat
    min_service = np.minimum(FAST.latency(PROF, wl.batches),
                             SLOW.latency(PROF, wl.batches))
    # simulator runs float32; allow for rounding
    assert np.all(lat >= min_service * (1 - 1e-5) - 1e-6)


def test_single_instance_serializes():
    wl = _wl(n=50, rate=500.0)   # heavy overload on one instance
    sim = PoolSimulator(PROF, [FAST, SLOW], wl, max_instances=8)
    lat = sim.simulate((1, 0)).lat
    svc = FAST.latency(PROF, wl.batches)
    finish = wl.arrivals + lat
    start = finish - svc
    # non-overlapping service windows on the single instance
    assert np.all(start[1:] >= (start[:-1] + svc[:-1]) - 1e-6)


def test_more_fast_instances_weakly_better_qos():
    wl = _wl(n=400, rate=300.0)
    sim = PoolSimulator(PROF, [FAST, SLOW], wl, max_instances=10)
    rates = [float(sim.qos((k, 0)).rates) for k in (1, 2, 4, 6)]
    assert all(b >= a - 0.01 for a, b in zip(rates, rates[1:]))
    assert rates[-1] > rates[0]


def test_empty_pool_all_violations():
    wl = _wl(n=20)
    sim = PoolSimulator(PROF, [FAST, SLOW], wl, max_instances=4)
    assert float(sim.qos((0, 0)).rates) == 0.0


def test_type_order_priority():
    """With both types idle, the first type in pool order must be used."""
    arrivals = np.array([0.0, 10.0, 20.0])  # fully spaced out: no queueing
    batches = np.array([8, 8, 8])
    wl = Workload(arrivals=arrivals, batches=batches, rate_qps=0.1)
    sim = PoolSimulator(PROF, [SLOW, FAST], wl, max_instances=4)
    lat = sim.simulate((1, 1)).lat  # SLOW listed first → every query on SLOW
    svc_slow = SLOW.latency(PROF, batches)
    np.testing.assert_allclose(lat, svc_slow, rtol=1e-5)


def test_workload_scaling():
    wl = _wl(n=100, rate=100.0)
    hot = wl.scaled(2.0)
    assert hot.rate_qps == pytest.approx(200.0)
    np.testing.assert_allclose(hot.arrivals, wl.arrivals / 2.0)
    np.testing.assert_array_equal(hot.batches, wl.batches)


# --------------------------------------------- continuous-time warm starts
def _slice(wl, lo, hi):
    return Workload(arrivals=wl.arrivals[lo:hi], batches=wl.batches[lo:hi],
                    rate_qps=wl.rate_qps)


def test_idle_carry_reproduces_cold_paths_bit_for_bit():
    """initial_state() is the identity element of every *_from entry."""
    wl = _wl(n=300, rate=200.0)
    sim = PoolSimulator(PROF, [FAST, SLOW], wl, max_instances=8)
    for cfg in ((1, 0), (2, 1), (3, 3)):
        warm = sim.simulate(cfg, state=sim.initial_state())
        cold = sim.simulate(cfg)
        np.testing.assert_array_equal(warm.lat, cold.lat)
        np.testing.assert_array_equal(warm.waits, cold.waits)
        rate = sim.qos(cfg, state=sim.initial_state()).rates
        assert rate == float(sim.qos(cfg).rates)


def test_warm_chained_segments_bit_identical_to_whole_stream():
    """Serving a stream in consecutive warm segments reproduces the one-shot
    scan exactly — the continuity contract the scenario engine rides on."""
    wl = _wl(n=400, rate=250.0)
    whole = PoolSimulator(PROF, [FAST, SLOW], wl, max_instances=8)
    cfg = (2, 1)
    want = whole.simulate(cfg).lat
    got, state = [], None
    for lo, hi in ((0, 90), (90, 91), (91, 250), (250, 400)):
        sim = PoolSimulator(PROF, [FAST, SLOW], _slice(wl, lo, hi),
                            max_instances=8)
        state = state or sim.initial_state()
        r = sim.simulate(cfg, state=state)
        state = r.state
        got.append(r.lat)
    np.testing.assert_array_equal(want, np.concatenate(got))


def test_segment_prefix_carry_matches_device_carry():
    """state_at(k) (the engine's rollback commit) equals the carry of an
    actual scan over the first k queries, bit for bit."""
    wl = _wl(n=300, rate=250.0)
    sim = PoolSimulator(PROF, [FAST, SLOW], wl, max_instances=8)
    cfg = (2, 2)
    seg = sim.segment_from(sim.initial_state(), cfg)
    for k in (0, 1, 137, 300):
        head = PoolSimulator(PROF, [FAST, SLOW], _slice(wl, 0, k),
                             max_instances=8)
        carry = head.simulate(cfg, state=head.initial_state()).state
        np.testing.assert_array_equal(seg.state_at(k).free[:4],
                                      carry.free[:4])


def test_remap_threads_survivors_drops_removed_adds_idle():
    free = np.array([5.0, 6.0, 7.0, 8.0, 9.0, 0.0], dtype=np.float64)
    state = PoolState(free=free, clock=2.0)
    # type 0: 2 -> 1 (slot 1 dropped); type 1: 3 -> 4 (one slot added)
    out = state.remap((2, 3), (1, 4), now=10.0)
    assert out.clock == 2.0
    # survivor of type 0 keeps its in-flight work
    assert out.free[0] == 5.0
    # type 1 survivors shift into slots 1..3, added slot idles at `now`
    np.testing.assert_array_equal(out.free[1:4], [7.0, 8.0, 9.0])
    assert out.free[4] == 10.0
    with pytest.raises(ValueError):
        state.remap((2, 3), (1,), now=0.0)
    with pytest.raises(ValueError):
        state.remap((2, 3), (4, 4), now=0.0)


def test_carried_wait_counts_only_future_busy_time():
    state = PoolState(free=np.array([4.0, 1.0, 9.0, 0.0]), clock=1.0)
    sim = PoolSimulator(PROF, [FAST, SLOW], _wl(n=20), max_instances=4)
    # local frame: rel free = [3.0, 0.0, 8.0]; at t=2 the backlog is
    # (3-2) + 0 + (8-2) = 7 over the three active slots
    assert sim.carried_wait(state, (2, 1), at=2.0) == pytest.approx(7.0)
    assert sim.carried_wait(state, (0, 0), at=2.0) == 0.0


def test_horizon_guard_rejects_big_timestamps():
    """Timestamps near the _BIG dispatch-priority envelope raise instead of
    silently corrupting slot choice."""
    arr = np.array([1.0, 2.0, 2.0 * _MAX_HORIZON])
    wl = Workload(arrivals=arr, batches=np.array([4, 4, 4]), rate_qps=1.0)
    with pytest.raises(ValueError, match="envelope"):
        PoolSimulator(PROF, [FAST, SLOW], wl, max_instances=4)
    # a warm carry whose backlog exceeds the envelope is rejected too
    sim = PoolSimulator(PROF, [FAST, SLOW], _wl(n=20), max_instances=4)
    bad = PoolState(free=np.full(4, 2.0 * _MAX_HORIZON), clock=0.0)
    with pytest.raises(ValueError, match="envelope"):
        sim.simulate((1, 1), state=bad)
    # rebasing the clock back under the envelope makes the same state fine
    ok = bad.rebased(2.0 * _MAX_HORIZON)
    lat = sim.simulate((1, 1), state=ok).lat
    assert np.isfinite(lat).all()
