"""FCFS pool simulator: invariants + equivalence with a pure-python oracle."""

import numpy as np
import pytest

from repro.serving.instance import InstanceType, ModelProfile
from repro.serving.simulator import PoolSimulator
from repro.serving.workload import Workload, generate_workload

FAST = InstanceType("fast", price=1.0, flops=1e9, mem_bw=1e9, overhead=1e-3)
SLOW = InstanceType("slow", price=0.3, flops=2e8, mem_bw=5e8, overhead=2e-3)
PROF = ModelProfile("toy", flops_per_sample=1e6, act_bytes_per_sample=1e4,
                    weight_bytes=1e5, qos_latency=0.05)


def _wl(seed=0, n=200, rate=120.0):
    return generate_workload(seed, n, rate, median_batch=8.0, max_batch=32)


def python_fcfs_oracle(workload: Workload, types, counts, profile):
    """Straightforward FCFS reference: first idle instance in type order,
    else earliest-freeing instance."""
    slots = []
    for t_idx, c in enumerate(counts):
        slots += [t_idx] * c
    free = [0.0] * len(slots)
    lat = []
    for arr, b in zip(workload.arrivals, workload.batches):
        idle = [i for i, f in enumerate(free) if f <= arr]
        pick = idle[0] if idle else int(np.argmin(free))
        start = max(arr, free[pick])
        svc = float(types[slots[pick]].latency(profile, b))
        free[pick] = start + svc
        lat.append(free[pick] - arr)
    return np.array(lat)


@pytest.mark.parametrize("counts", [(1, 0), (2, 0), (1, 2), (3, 3), (0, 2)])
def test_scan_matches_python_oracle(counts):
    wl = _wl()
    sim = PoolSimulator(PROF, [FAST, SLOW], wl, max_instances=8)
    got = sim.latencies(counts)
    want = python_fcfs_oracle(wl, [FAST, SLOW], counts, PROF)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-6)


def test_latency_at_least_service_time():
    wl = _wl()
    sim = PoolSimulator(PROF, [FAST, SLOW], wl, max_instances=8)
    lat = sim.latencies((2, 1))
    min_service = np.minimum(FAST.latency(PROF, wl.batches),
                             SLOW.latency(PROF, wl.batches))
    # simulator runs float32; allow for rounding
    assert np.all(lat >= min_service * (1 - 1e-5) - 1e-6)


def test_single_instance_serializes():
    wl = _wl(n=50, rate=500.0)   # heavy overload on one instance
    sim = PoolSimulator(PROF, [FAST, SLOW], wl, max_instances=8)
    lat = sim.latencies((1, 0))
    svc = FAST.latency(PROF, wl.batches)
    finish = wl.arrivals + lat
    start = finish - svc
    # non-overlapping service windows on the single instance
    assert np.all(start[1:] >= (start[:-1] + svc[:-1]) - 1e-6)


def test_more_fast_instances_weakly_better_qos():
    wl = _wl(n=400, rate=300.0)
    sim = PoolSimulator(PROF, [FAST, SLOW], wl, max_instances=10)
    rates = [sim.qos_rate((k, 0)) for k in (1, 2, 4, 6)]
    assert all(b >= a - 0.01 for a, b in zip(rates, rates[1:]))
    assert rates[-1] > rates[0]


def test_empty_pool_all_violations():
    wl = _wl(n=20)
    sim = PoolSimulator(PROF, [FAST, SLOW], wl, max_instances=4)
    assert sim.qos_rate((0, 0)) == 0.0


def test_type_order_priority():
    """With both types idle, the first type in pool order must be used."""
    arrivals = np.array([0.0, 10.0, 20.0])  # fully spaced out: no queueing
    batches = np.array([8, 8, 8])
    wl = Workload(arrivals=arrivals, batches=batches, rate_qps=0.1)
    sim = PoolSimulator(PROF, [SLOW, FAST], wl, max_instances=4)
    lat = sim.latencies((1, 1))  # SLOW listed first → every query on SLOW
    svc_slow = SLOW.latency(PROF, batches)
    np.testing.assert_allclose(lat, svc_slow, rtol=1e-5)


def test_workload_scaling():
    wl = _wl(n=100, rate=100.0)
    hot = wl.scaled(2.0)
    assert hot.rate_qps == pytest.approx(200.0)
    np.testing.assert_allclose(hot.arrivals, wl.arrivals / 2.0)
    np.testing.assert_array_equal(hot.batches, wl.batches)
