"""Live execution plane: ClusterEngine with real (smoke-scale) models."""

import pytest

from repro.serving.autoscaler import LoadMonitor
from repro.serving.engine import CellType, ClusterEngine
from repro.serving.workload import generate_workload

CELLS = [CellType("cell1", price=1.2, chips=1, speed=1.0),
         CellType("cell4", price=4.8, chips=4, speed=3.0)]


@pytest.fixture(scope="module")
def engine():
    eng = ClusterEngine("mtwnd", CELLS, seed=0)
    return eng


def test_configure_and_price(engine):
    engine.configure((2, 1))
    assert len(engine.cells) == 3
    assert engine.pool_price() == pytest.approx(2 * 1.2 + 4.8)
    assert engine.pool_price((1, 2)) == pytest.approx(1.2 + 9.6)


def test_serve_real_queries(engine):
    engine.configure((2, 1))
    wl = generate_workload(0, 30, rate_qps=50.0, median_batch=4, max_batch=16)
    rate = engine.serve(wl, qos_latency=10.0, time_scale=1.0)
    assert 0.0 <= rate <= 1.0
    assert len(engine.records) == 30
    # every query actually executed on some cell
    assert sum(c.n_served for c in engine.cells) >= 30
    # with an absurdly generous target everything satisfies
    assert engine.serve(wl, qos_latency=1e6) == 1.0


def test_fail_cell_shrinks_pool(engine):
    engine.configure((2, 1))
    lost = engine.fail_cell(0)
    assert lost.name == "cell1"
    assert engine.active_config() == (1, 1)
    wl = generate_workload(1, 10, rate_qps=20.0, median_batch=4, max_batch=8)
    rate = engine.serve(wl, qos_latency=1e6)
    assert rate == 1.0   # surviving cells still serve everything


def test_empty_pool_serves_nothing(engine):
    engine.configure((0, 0))
    wl = generate_workload(2, 5, rate_qps=10.0, median_batch=4, max_batch=8)
    assert engine.serve(wl, qos_latency=1.0) == 0.0


def test_serve_records_waits_and_feeds_monitor(engine):
    """The measured plane exposes (latencies, waits) windows so the load
    monitor works on real records, not just the simulator."""
    engine.configure((2, 1))
    wl = generate_workload(4, 30, rate_qps=200.0, median_batch=4,
                           max_batch=16)
    engine.serve(wl, qos_latency=10.0)
    lat, waits = engine.served_arrays()
    assert lat.shape == waits.shape == (30,)
    assert (waits >= 0).all()
    assert (lat >= waits).all()           # wait is part of the latency
    assert all(r.wait >= 0 for r in engine.records)
    mon = LoadMonitor(qos_target=0.99)
    assert mon.observe(lat, waits, qos_latency=10.0) is False   # baseline
    assert isinstance(mon.observe(lat, waits, 10.0), bool)


def test_empty_pool_clears_stale_records(engine):
    engine.configure((2, 1))
    wl = generate_workload(5, 8, rate_qps=20.0, median_batch=4, max_batch=8)
    engine.serve(wl, qos_latency=1e6)
    engine.configure((0, 0))
    assert engine.serve(wl, qos_latency=1e6) == 0.0
    lat, waits = engine.served_arrays()
    assert lat.size == 0 and waits.size == 0


def test_preempt_hook(engine):
    engine.configure((2, 1))
    assert engine.preempt(0, 1) == 1
    assert engine.active_config() == (1, 1)
    assert engine.preempt(1, 5) == 1      # only one cell4 to reclaim
    assert engine.active_config() == (1, 0)
    assert engine.preempt(1, 1) == 0      # nothing left of that type
    # re-provisioning clears the preempted pool
    engine.configure((1, 1))
    assert engine.active_config() == (1, 1)


def test_type_order_priority_live(engine):
    """First idle cell in pool-type order takes the query (paper §5.1)."""
    engine.configure((1, 1))
    wl = generate_workload(3, 6, rate_qps=0.01, median_batch=2, max_batch=4)
    engine.serve(wl, qos_latency=1e6)
    # with fully spaced arrivals every query lands on the first type
    assert all(r.cell == "cell1" for r in engine.records)


def test_serve_warm_start_initial_busy(engine):
    """`initial_busy` warm-starts the virtual clock: a carried backlog
    delays every start, the per-query slot trace names the advanced cell,
    and a mismatched vector is rejected."""
    engine.configure((2, 1))
    wl = generate_workload(6, 15, rate_qps=100.0, median_batch=4,
                           max_batch=8)
    engine.serve(wl, qos_latency=1e6)
    assert all(0 <= r.slot < 3 for r in engine.records)
    # every cell starts 5 virtual seconds busy: all queries arrive earlier
    # (span ~0.15s at 100 qps) and must queue behind the carried work
    engine.serve(wl, qos_latency=1e6, initial_busy=[5.0, 5.0, 5.0])
    _, waits = engine.served_arrays()
    assert (waits >= 4.0).all()
    assert all(c.busy_until >= 5.0 for c in engine.cells)
    with pytest.raises(ValueError):
        engine.serve(wl, qos_latency=1e6, initial_busy=[5.0])
