"""Model-substrate correctness: per-arch smoke tests + the decode invariant.

The decode invariant is the strongest cache test: running prefill on a prompt
and then decode_step for the next token must produce the same logits (within
fp tolerance) as one full forward pass over the prompt + token.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS
from repro.models.transformer import get_model

ALL_ARCHS = sorted(ARCHS)


def _inputs(cfg, key, batch=2, seq=16):
    kt, ke = jax.random.split(key)
    tokens = jax.random.randint(kt, (batch, seq), 0, cfg.vocab_size)
    extra = None
    if cfg.family == "vlm":
        extra = jax.random.normal(ke, (batch, cfg.n_patches, cfg.d_model)) * 0.1
    if cfg.family == "encdec":
        extra = jax.random.normal(ke, (batch, cfg.encoder_seq, cfg.d_model)) * 0.1
    return tokens, extra


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_smoke_forward_train_step(arch):
    """Reduced config: one forward + one grad step on CPU; shapes + no NaNs."""
    cfg = ARCHS[arch].reduced()
    api = get_model(cfg)
    key = jax.random.PRNGKey(0)
    params = api.init_params(key, jnp.float32)
    tokens, extra = _inputs(cfg, key)
    logits, aux = api.forward(params, tokens, extra)
    assert logits.shape[-1] == cfg.vocab_size
    assert logits.shape[0] == tokens.shape[0]
    assert not np.any(np.isnan(np.asarray(logits, dtype=np.float32)))

    loss, grads = jax.value_and_grad(api.loss)(params, tokens, tokens, extra)
    assert np.isfinite(float(loss))
    flat = jax.tree_util.tree_leaves(grads)
    assert all(np.all(np.isfinite(np.asarray(g, dtype=np.float32)))
               for g in flat)
    # gradients actually flow to the embedding and deepest layer
    assert float(jnp.abs(grads["embed"]).max()) > 0


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_decode_matches_forward(arch):
    """prefill(prompt) + decode(next) ≡ forward(prompt+next)[-1]."""
    cfg = ARCHS[arch].reduced()
    api = get_model(cfg)
    key = jax.random.PRNGKey(1)
    params = api.init_params(key, jnp.float32)
    batch, seq = 2, 12
    tokens, extra = _inputs(cfg, key, batch, seq + 1)
    prompt, nxt = tokens[:, :seq], tokens[:, seq:seq + 1]

    full_logits, _ = api.forward(params, tokens, extra)
    want = np.asarray(full_logits[:, -1], dtype=np.float32)

    max_len = seq + 8 + (cfg.n_patches if cfg.family == "vlm" else 0)
    cache, last = api.prefill(params, prompt, max_len=max_len, extra=extra)
    got_prefill = np.asarray(last[:, 0], dtype=np.float32)
    # prefill's last-position logits must match forward at that position
    # (forward emits logits for every position incl. the VLM patch prefix)
    prefix = cfg.n_patches if cfg.family == "vlm" else 0
    np.testing.assert_allclose(
        got_prefill,
        np.asarray(full_logits[:, prefix + seq - 1], dtype=np.float32),
        rtol=2e-3, atol=2e-3)

    logits, cache = api.decode_step(params, cache, nxt)
    got = np.asarray(logits[:, 0], dtype=np.float32)
    np.testing.assert_allclose(got, want, rtol=2e-3, atol=2e-3)


@pytest.mark.parametrize("arch", ["mixtral-8x22b", "zamba2-2.7b"])
def test_sliding_window_ring_cache_multi_step(arch):
    """Decode several steps past the window size: ring cache must keep
    matching the windowed full-attention forward."""
    cfg = ARCHS[arch].reduced()     # window reduced to 16
    api = get_model(cfg)
    key = jax.random.PRNGKey(2)
    params = api.init_params(key, jnp.float32)
    batch = 2
    total = cfg.sliding_window + 6   # decode beyond one window
    tokens, extra = _inputs(cfg, key, batch, total)
    prompt_len = cfg.sliding_window - 2

    cache, _ = api.prefill(params, tokens[:, :prompt_len],
                           max_len=total, extra=extra)
    for i in range(prompt_len, total):
        logits, cache = api.decode_step(params, cache, tokens[:, i:i + 1])
    full_logits, _ = api.forward(params, tokens, extra)
    np.testing.assert_allclose(
        np.asarray(logits[:, 0], dtype=np.float32),
        np.asarray(full_logits[:, -1], dtype=np.float32),
        rtol=5e-3, atol=5e-3)


def test_moe_aux_loss_positive_and_bounded():
    cfg = ARCHS["olmoe-1b-7b"].reduced()
    api = get_model(cfg)
    params = api.init_params(jax.random.PRNGKey(0), jnp.float32)
    tokens, _ = _inputs(cfg, jax.random.PRNGKey(3))
    _, aux = api.forward(params, tokens, None)
    # Switch-style aux loss ~1 for balanced routing
    assert 0.0 < float(aux) < 10.0 * cfg.n_layers


def test_vlm_patch_prefix_changes_logits():
    cfg = ARCHS["internvl2-1b"].reduced()
    api = get_model(cfg)
    params = api.init_params(jax.random.PRNGKey(0), jnp.float32)
    key = jax.random.PRNGKey(4)
    tokens, extra = _inputs(cfg, key)
    l1, _ = api.forward(params, tokens, extra)
    l2, _ = api.forward(params, tokens, extra * 2.0)
    assert not np.allclose(np.asarray(l1[:, -1]), np.asarray(l2[:, -1]))


def test_banded_sliding_window_attention_exact():
    """The banded SWA fast path (K sliced to the window band per q-block)
    must equal naive windowed attention exactly."""
    import jax
    import jax.numpy as jnp
    from repro.models.layers import (attention_core, attention_full,
                                     causal_window_mask)
    key = jax.random.PRNGKey(7)
    b, s, h, d = 1, 1024, 2, 32
    q = jax.random.normal(key, (b, s, h, d)) * 0.5
    k = jax.random.normal(jax.random.fold_in(key, 1), (b, s, h, d)) * 0.5
    v = jax.random.normal(jax.random.fold_in(key, 2), (b, s, h, d)) * 0.5
    pos = jnp.arange(s, dtype=jnp.int32)
    for window in (64, 300):
        banded = attention_full(q, k, v, pos, pos, window, d ** -0.5,
                                q_block=256)
        mask = causal_window_mask(pos[None], pos[None], window)[:, None]
        naive = attention_core(q, k, v, mask, d ** -0.5)
        np.testing.assert_allclose(np.asarray(banded), np.asarray(naive),
                                   rtol=1e-5, atol=1e-5)
