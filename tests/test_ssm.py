"""Mamba2/SSD correctness: chunked scan ≡ sequential recurrence ≡ decode."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS
from repro.models.ssm import (init_ssm_params, ssd_chunked,
                              ssd_reference_sequential, ssm_decode_step,
                              ssm_forward)


def _ssd_inputs(key, b=2, slen=32, h=4, p=8, g=2, n=8):
    ks = jax.random.split(key, 4)
    x = jax.random.normal(ks[0], (b, slen, h, p)) * 0.5
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b, slen, h)))
    a_log = jnp.log(jnp.linspace(0.5, 4.0, h))
    bb = jax.random.normal(ks[2], (b, slen, g, n)) * 0.5
    cc = jax.random.normal(ks[3], (b, slen, g, n)) * 0.5
    return x, dt, a_log, bb, cc


@pytest.mark.parametrize("chunk", [4, 8, 16, 32])
def test_chunked_matches_sequential(chunk):
    x, dt, a_log, b, c = _ssd_inputs(jax.random.PRNGKey(0))
    y_chunk, s_chunk = ssd_chunked(x, dt, a_log, b, c, chunk)
    y_seq, s_seq = ssd_reference_sequential(x, dt, a_log, b, c)
    np.testing.assert_allclose(np.asarray(y_chunk), np.asarray(y_seq),
                               rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(s_chunk), np.asarray(s_seq),
                               rtol=2e-4, atol=2e-4)


def test_chunk_invariance():
    x, dt, a_log, b, c = _ssd_inputs(jax.random.PRNGKey(1), slen=24)
    y1, s1 = ssd_chunked(x, dt, a_log, b, c, 8)
    y2, s2 = ssd_chunked(x, dt, a_log, b, c, 24)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), rtol=2e-4,
                               atol=2e-4)
    np.testing.assert_allclose(np.asarray(s1), np.asarray(s2), rtol=2e-4,
                               atol=2e-4)


def test_forward_then_decode_continuity():
    """Prefill carry + token-by-token decode ≡ one long forward."""
    cfg = ARCHS["mamba2-130m"].reduced()
    key = jax.random.PRNGKey(2)
    params = init_ssm_params(key, cfg, jnp.float32)
    b, l_pre, l_dec = 2, 16, 4
    x = jax.random.normal(key, (b, l_pre + l_dec, cfg.d_model)) * 0.3

    y_full, _ = ssm_forward(params, x, cfg)

    y_pre, carry = ssm_forward(params, x[:, :l_pre], cfg)
    np.testing.assert_allclose(np.asarray(y_pre), np.asarray(y_full[:, :l_pre]),
                               rtol=1e-4, atol=1e-4)
    outs = []
    for i in range(l_dec):
        y_i, carry = ssm_decode_step(params, x[:, l_pre + i:l_pre + i + 1],
                                     cfg, carry)
        outs.append(np.asarray(y_i[:, 0]))
    np.testing.assert_allclose(np.stack(outs, axis=1),
                               np.asarray(y_full[:, l_pre:]),
                               rtol=5e-4, atol=5e-4)


def test_state_decays_with_positive_dt():
    """exp(dt*A) must be strictly in (0,1): state can't blow up."""
    x, dt, a_log, b, c = _ssd_inputs(jax.random.PRNGKey(3), slen=64)
    _, s = ssd_chunked(x, dt, a_log, b, c, 16)
    assert np.all(np.isfinite(np.asarray(s)))
