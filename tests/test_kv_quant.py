"""int8 KV-cache quantization (beyond-paper perf variant)."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS
from repro.models.cache import dequantize_kv, quantize_kv
from repro.models.transformer import get_model


def test_quantize_roundtrip_error_bounded():
    key = jax.random.PRNGKey(0)
    x = jax.random.normal(key, (4, 8, 64)) * 3.0
    q, s = quantize_kv(x)
    assert q.dtype == jnp.int8
    back = dequantize_kv(q, s, x.dtype)
    err = np.abs(np.asarray(back - x))
    # per-vector scale → error bounded by scale/2 per element
    bound = np.asarray(s)[..., None] * 0.5 + 1e-6
    assert np.all(err <= bound)


def test_quantize_zero_vector_safe():
    q, s = quantize_kv(jnp.zeros((2, 4)))
    assert np.all(np.asarray(q) == 0)
    assert np.all(np.isfinite(np.asarray(s)))


@pytest.mark.parametrize("arch", ["qwen2.5-3b", "olmoe-1b-7b"])
def test_int8_kv_decode_approximates_forward(arch):
    cfg = dataclasses.replace(ARCHS[arch].reduced(), kv_quant_int8=True)
    api = get_model(cfg)
    key = jax.random.PRNGKey(1)
    params = api.init_params(key, jnp.float32)
    tokens = jax.random.randint(key, (2, 13), 0, cfg.vocab_size)
    full, _ = api.forward(params, tokens, None)
    cache, _ = api.prefill(params, tokens[:, :12], max_len=20)
    assert cache["k"].dtype == jnp.int8
    logits, cache = api.decode_step(params, cache, tokens[:, 12:13])
    want = np.asarray(full[:, -1])
    got = np.asarray(logits[:, 0])
    rel = np.abs(got - want).max() / (np.abs(want).max() + 1e-9)
    assert rel < 0.05, f"int8 KV degraded logits: rel err {rel:.4f}"


def test_int8_kv_multi_step_consistency():
    """Several decode steps with the quantized ring stay close to fp."""
    base = ARCHS["qwen2.5-3b"].reduced()
    api_fp = get_model(base)
    api_q8 = get_model(dataclasses.replace(base, kv_quant_int8=True))
    key = jax.random.PRNGKey(2)
    params = api_fp.init_params(key, jnp.float32)
    tokens = jax.random.randint(key, (2, 16), 0, base.vocab_size)
    c_fp, _ = api_fp.prefill(params, tokens[:, :10], max_len=24)
    c_q8, _ = api_q8.prefill(params, tokens[:, :10], max_len=24)
    for i in range(10, 16):
        l_fp, c_fp = api_fp.decode_step(params, c_fp, tokens[:, i:i + 1])
        l_q8, c_q8 = api_q8.decode_step(params, c_q8, tokens[:, i:i + 1])
    # same argmax token at the end (the serving-level invariant)
    assert np.array_equal(np.argmax(np.asarray(l_fp), -1),
                          np.argmax(np.asarray(l_q8), -1))
