"""Structural calibration of the instance catalog against paper Figs. 3/4 and
Table 3 (see instance.py docstring for the deviation notes)."""

import numpy as np
import pytest

from repro.serving import (AWS_INSTANCES, MODEL_PROFILES, PAPER_POOLS,
                           PoolEvaluator, best_homogeneous, generate_workload)
from repro.serving.pool import DEFAULT_RATES

ALL = list(AWS_INSTANCES)


def _lat(model, name, b):
    return float(AWS_INSTANCES[name].latency(MODEL_PROFILES[model], b))


def test_fig3a_perf_ranking_flips_with_batch():
    """GPU clearly best at batch 128 (>1.4x margin), near-parity at 32."""
    lat128 = {n: _lat("mtwnd", n, 128) for n in ALL}
    best = min(lat128, key=lat128.get)
    assert best == "g4dn"
    second = sorted(lat128.values())[1]
    assert second / lat128["g4dn"] > 1.4

    lat32 = {n: _lat("mtwnd", n, 32) for n in ALL}
    spread = max(lat32.values()) / min(lat32.values())
    assert spread < 3.0   # "similarly high performance"


def test_fig3b_cost_effectiveness_ranking():
    """r5 most cost-effective, g4dn least — at small batch (paper Fig. 3b)."""
    for model in ("mtwnd", "dien"):
        ce = {n: 1.0 / (_lat(model, n, 32) * AWS_INSTANCES[n].price)
              for n in ALL}
        assert max(ce, key=ce.get) in ("r5", "r5n")
        assert min(ce, key=ce.get) == "g4dn"


def test_recsys_only_gpu_serves_large_batches_within_qos():
    """§3.2: cost-effective types violate QoS for large batches; the GPU is
    the only type meeting the 20ms target at the batch-size cap."""
    prof = MODEL_PROFILES["mtwnd"]
    for n in ALL:
        ok = _lat("mtwnd", n, prof.max_batch) <= prof.qos_latency
        assert ok == (n == "g4dn"), n


def test_cheap_types_serve_small_batches_within_qos():
    prof = MODEL_PROFILES["mtwnd"]
    for n in ("r5n", "c5", "t3"):
        assert _lat("mtwnd", n, 32) <= prof.qos_latency


@pytest.mark.slow
@pytest.mark.parametrize("model", ["mtwnd", "candle"])
def test_table3_homogeneous_optimum(model):
    """Cost-optimal homogeneous type matches paper Table 3."""
    prof = MODEL_PROFILES[model]
    wl = generate_workload(0, 1200, DEFAULT_RATES[model],
                           median_batch=prof.median_batch,
                           max_batch=prof.max_batch)
    types = [AWS_INSTANCES[n] for n in ALL]
    ev = PoolEvaluator(prof, types, wl)
    prices = [t.price for t in types]
    best_name, best_cost = None, np.inf
    for i, n in enumerate(ALL):
        cnt, cost = best_homogeneous(ev, i, prices, 0.99, cap=20)
        if cnt is not None and cost < best_cost:
            best_name, best_cost = n, cost
    assert best_name == PAPER_POOLS[model]["homogeneous"]
