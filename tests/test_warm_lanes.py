"""Warm-start batched/grid evaluation lanes: what-if candidate scoring from
a live backlog.

Contracts under test (the regression anchors of the warm lanes):

* **idle anchors** — started from ``initial_state()`` every warm lane
  reproduces its cold counterpart bit for bit (warm batch == cold batch,
  warm grid == cold grid, stacked tables included);
* **per-row bit-identity** — row ``i`` of a warm batch (cell ``[w, b]`` of
  a warm grid) equals the sequential ``*_from`` path on that candidate's
  remapped state, exactly — fuzzed over random pools/streams/states via
  the hypothesis shim;
* **remap round-trips** — ``remap`` to self is the identity on the active
  prefix, remap-then-remap-back preserves surviving slots' carries, and
  the vectorized ``remap_batch`` matches per-row sequential ``remap``;
* **warm-keyed memoization** — ``PoolEvaluator.grid_from`` caches per
  (state, deployed, now) key, LRU-bounds the per-state caches, and the
  idle key reproduces the cold ``grid`` bits;
* **rescale integration** — ``rescale(warm_state=...)`` scores candidates
  (and ``qos_by_load``) through the warm lanes.
"""

import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core import RibbonOptimizer
from repro.core.search_space import SearchSpace
from repro.serving.autoscaler import rescale
from repro.serving.instance import (InstanceType, ModelProfile,
                                    service_time_table)
from repro.serving.pool import PoolEvaluator
from repro.serving.simulator import PoolSimulator, PoolState
from repro.serving.workload import generate_workload

FAST = InstanceType("fast", price=1.0, flops=1e9, mem_bw=1e9, overhead=1e-3)
SLOW = InstanceType("slow", price=0.3, flops=2e8, mem_bw=5e8, overhead=2e-3)
PROF = ModelProfile("toy", flops_per_sample=1e6, act_bytes_per_sample=1e4,
                    weight_bytes=1e5, qos_latency=0.05)
MAX_INST = 8
FACTORS = (1.0, 1.3, 1.7)

_SIM = None


def _workload(seed=0, n=150, rate=150.0):
    return generate_workload(seed, n, rate, median_batch=8.0, max_batch=32)


def _sim(wl=None):
    return PoolSimulator(PROF, [FAST, SLOW], wl or _workload(),
                         max_instances=MAX_INST)


def _shared_sim():
    """One module-wide simulator for the property sweeps: a fixed stream
    shape keeps every example on the already-compiled executables."""
    global _SIM
    if _SIM is None:
        _SIM = _sim()
    return _SIM


def _configs(n=6, seed=0):
    rng = np.random.default_rng(seed)
    cfgs = rng.integers(0, 5, size=(n, 2))
    cfgs[0] = (0, 0)                              # empty pool
    cfgs[1] = (MAX_INST // 2, MAX_INST // 2)      # max-capacity padding
    return cfgs


def _backlog_state(sim, deployed=(1, 1), upto=90):
    """A genuinely backlogged carry: the stream's first ``upto`` queries
    served on a lean pool, rebased so the carry's clock sits at the cut."""
    seg = sim.segment_from(sim.initial_state(), deployed)
    return seg.state_at(upto).rebased(float(sim.workload.arrivals[upto - 1]))


# ------------------------------------------------------------ idle anchors
def test_idle_batch_from_reproduces_cold_batch_bit_for_bit():
    sim = _shared_sim()
    cfgs = _configs()
    lat = sim.simulate(cfgs, state=sim.initial_state()).lat
    np.testing.assert_array_equal(lat, sim.simulate(cfgs).lat)
    rates = sim.qos(cfgs, state=sim.initial_state()).rates
    np.testing.assert_array_equal(rates, sim.qos(cfgs).rates)
    # remapping *from* an idle pool at clock 0 is still the idle carry
    rates2 = sim.qos(cfgs, state=sim.initial_state(),
                     deployed=(1, 1)).rates
    np.testing.assert_array_equal(rates2, rates)


def test_idle_grid_from_reproduces_cold_grid_bit_for_bit():
    sim = _shared_sim()
    cfgs = _configs(seed=1)
    np.testing.assert_array_equal(
        sim.qos(cfgs, workloads=FACTORS,
                state=sim.initial_state()).rates,
        sim.qos(cfgs, workloads=FACTORS).rates)
    np.testing.assert_array_equal(
        sim.simulate(cfgs, workloads=FACTORS,
                     state=sim.initial_state()).lat,
        sim.simulate(cfgs, workloads=FACTORS).lat)


def test_idle_grid_from_with_stacked_tables_matches_cold():
    wl_ln = _workload(seed=2)
    wl_ga = generate_workload(2, 150, 150.0, batch_dist="gaussian",
                              mean_batch=10.0, std_batch=4.0, max_batch=32)
    sim = _sim(wl_ln)
    cfgs = _configs(seed=2)
    tables = np.stack([
        service_time_table(PROF, [FAST, SLOW], wl_ln.batches),
        service_time_table(PROF, [FAST, SLOW], wl_ga.batches)])
    factors = (1.0, 1.5)
    np.testing.assert_array_equal(
        sim.qos(cfgs, workloads=factors, service_tables=tables,
                state=sim.initial_state()).rates,
        sim.qos(cfgs, workloads=factors,
                service_tables=tables).rates)


# ------------------------------------------------------ warm bit-identity
def test_warm_batch_rows_bit_equal_sequential_from():
    sim = _shared_sim()
    deployed = (1, 1)
    state = _backlog_state(sim, deployed)
    cfgs = _configs(seed=3)
    r = sim.simulate(cfgs, state=state, deployed=deployed)
    lat, states = r.lat, r.state
    rates = sim.qos(cfgs, state=state, deployed=deployed).rates
    for b, c in enumerate(cfgs):
        cfg = tuple(int(x) for x in c)
        s_b = state.remap(deployed, cfg, float(state.clock))
        ref = sim.simulate(cfg, state=s_b)
        lat_ref, state_ref = ref.lat, ref.state
        np.testing.assert_array_equal(lat[b], lat_ref)
        np.testing.assert_array_equal(states[b].free, state_ref.free)
        assert states[b].clock == state_ref.clock
        rate_ref = sim.qos(cfg, state=s_b).rates
        assert rates[b] == rate_ref


def test_warm_grid_cells_bit_equal_sequential_on_scaled_sims():
    wl = _workload(seed=4)
    sim = _sim(wl)
    deployed = (2, 0)
    state = _backlog_state(sim, deployed)
    cfgs = _configs(seed=4)
    rates = sim.qos(cfgs, workloads=FACTORS, state=state,
                    deployed=deployed).rates
    lat = sim.simulate(cfgs, workloads=FACTORS, state=state,
                       deployed=deployed).lat
    for w, f in enumerate(FACTORS):
        scaled = PoolSimulator(PROF, [FAST, SLOW], wl.scaled(f),
                               max_instances=MAX_INST)
        for b, c in enumerate(cfgs):
            cfg = tuple(int(x) for x in c)
            s_b = state.remap(deployed, cfg, float(state.clock))
            rate_ref = scaled.qos(cfg, state=s_b).rates
            assert rates[w, b] == rate_ref
            lat_ref = scaled.simulate(cfg, state=s_b).lat
            np.testing.assert_array_equal(lat[w, b], lat_ref)


def test_warm_scoring_differs_from_idle_under_real_backlog():
    """The point of the lanes: a carried backlog must actually move the
    scores (otherwise what-if adaptation would still be idle-optimistic)."""
    sim = _shared_sim()
    state = _backlog_state(sim, (1, 1))
    cfgs = _configs(seed=5)
    warm = sim.qos(cfgs, state=state, deployed=(1, 1)).rates
    idle = sim.qos(cfgs).rates
    assert np.abs(warm - idle).max() > 0.0


def test_warm_batch_empty_inputs_and_empty_stream():
    sim = _shared_sim()
    r0 = sim.simulate(np.zeros((0, 2), dtype=np.int64),
                      state=sim.initial_state())
    lat, states = r0.lat, r0.state
    assert lat.shape == (0, sim.workload.n_queries) and states == []
    # an empty stream passes every candidate's carry through unchanged
    empty = PoolSimulator(PROF, [FAST, SLOW], _workload(n=1),
                          max_instances=MAX_INST)
    state = PoolState(free=np.full(MAX_INST, 2.0), clock=1.0)
    sliced = empty.workload
    assert sliced.n_queries == 1            # single-query stream still runs
    r1 = empty.simulate([(1, 0), (0, 0)], state=state)
    lat1, states1 = r1.lat, r1.state
    assert lat1.shape == (2, 1)
    assert np.isinf(lat1[1]).all()          # empty pool: every query violates
    np.testing.assert_array_equal(states1[1].free, state.free)


def test_warm_lanes_reject_mismatched_state_padding():
    sim = _shared_sim()
    bad = PoolState.idle(MAX_INST + 1)
    with pytest.raises(ValueError, match="slots"):
        sim.qos([(1, 1)], state=bad)
    with pytest.raises(ValueError, match="slots"):
        sim.qos([(1, 1)], workloads=(1.0,), state=bad)


# ------------------------------------------------------- property sweeps
@settings(max_examples=8)
@given(st.tuples(st.integers(min_value=0, max_value=4),
                 st.integers(min_value=0, max_value=4)),
       st.floats(min_value=0.0, max_value=0.4),
       st.integers(min_value=0, max_value=10_000))
def test_prop_warm_batch_bit_equals_sequential(deployed, backlog, seed):
    """Random pools/streams/states: the warm batch lane bit-equals the
    warm single lane on the remapped per-candidate state."""
    sim = _shared_sim()
    rng = np.random.default_rng(seed)
    cfgs = rng.integers(0, 5, size=(4, 2))
    free = 3.0 + rng.uniform(0.0, max(backlog, 0.0), size=MAX_INST)
    state = PoolState(free=free, clock=3.0)
    rw = sim.qos(cfgs, state=state, deployed=deployed)
    rates, states = rw.rates, rw.state
    for b, c in enumerate(cfgs):
        cfg = tuple(int(x) for x in c)
        s_b = state.remap(deployed, cfg, float(state.clock))
        refq = sim.qos(cfg, state=s_b)
        rate_ref, state_ref = refq.rates, refq.state
        assert rates[b] == rate_ref
        np.testing.assert_array_equal(states[b].free, state_ref.free)


@settings(max_examples=6)
@given(st.integers(min_value=0, max_value=10_000),
       st.floats(min_value=1.0, max_value=2.0))
def test_prop_idle_grid_from_bit_equals_cold_grid(seed, factor):
    """Idle-state warm grid == cold grid for random configs and levels."""
    sim = _shared_sim()
    rng = np.random.default_rng(seed)
    cfgs = rng.integers(0, 5, size=(5, 2))
    factors = (1.0, float(factor))
    np.testing.assert_array_equal(
        sim.qos(cfgs, workloads=factors,
                state=sim.initial_state()).rates,
        sim.qos(cfgs, workloads=factors).rates)


@settings(max_examples=10)
@given(st.tuples(st.integers(min_value=0, max_value=4),
                 st.integers(min_value=0, max_value=4)),
       st.tuples(st.integers(min_value=0, max_value=4),
                 st.integers(min_value=0, max_value=4)),
       st.integers(min_value=0, max_value=10_000))
def test_prop_remap_round_trips(cfg_a, cfg_b, seed):
    """remap to self is the identity on the active prefix; remap there and
    back preserves the carries of slots that survive both hops."""
    rng = np.random.default_rng(seed)
    state = PoolState(free=rng.uniform(0.0, 5.0, size=MAX_INST), clock=1.0)
    now = 9.0
    self_mapped = state.remap(cfg_a, cfg_a, now)
    n_a = sum(cfg_a)
    np.testing.assert_array_equal(self_mapped.free[:n_a], state.free[:n_a])
    assert self_mapped.clock == state.clock
    fwd = state.remap(cfg_a, cfg_b, now)
    back = fwd.remap(cfg_b, cfg_a, now)
    ac = np.concatenate([[0], np.cumsum(cfg_a)])
    for t in range(len(cfg_a)):
        k = min(cfg_a[t], cfg_b[t])     # survivors of both hops, per type
        np.testing.assert_array_equal(back.free[ac[t]:ac[t] + k],
                                      state.free[ac[t]:ac[t] + k])


@settings(max_examples=8)
@given(st.tuples(st.integers(min_value=0, max_value=4),
                 st.integers(min_value=0, max_value=4)),
       st.integers(min_value=0, max_value=10_000))
def test_prop_remap_batch_matches_sequential_remap(deployed, seed):
    rng = np.random.default_rng(seed)
    state = PoolState(free=rng.uniform(0.0, 4.0, size=MAX_INST), clock=0.5)
    cfgs = rng.integers(0, 5, size=(6, 2))
    mat = state.remap_batch(deployed, cfgs, 2.5)
    assert mat.shape == (len(cfgs), MAX_INST)
    for b, c in enumerate(cfgs):
        np.testing.assert_array_equal(
            mat[b], state.remap(deployed, tuple(int(x) for x in c),
                                2.5).free)


def test_remap_batch_validates_shapes_and_padding():
    state = PoolState.idle(4)
    with pytest.raises(ValueError):
        state.remap_batch((1, 1), np.zeros((2, 3), dtype=np.int64), 0.0)
    with pytest.raises(ValueError):
        state.remap_batch((1, 1), np.array([[4, 4]]), 0.0)
    with pytest.raises(ValueError):
        state.remap_batch((4, 4), np.array([[1, 1]]), 0.0)


# ------------------------------------------------- evaluator memoization
def test_evaluator_grid_from_idle_key_matches_cold_grid():
    ev = PoolEvaluator(PROF, [FAST, SLOW], _workload(seed=6),
                       max_instances=MAX_INST)
    cfgs = [(1, 0), (2, 1), (0, 3)]
    np.testing.assert_array_equal(
        ev.grid_from(ev.sim.initial_state(), cfgs, FACTORS),
        ev.grid(cfgs, FACTORS))


def test_evaluator_grid_from_memoizes_per_warm_state():
    ev = PoolEvaluator(PROF, [FAST, SLOW], _workload(seed=7),
                       max_instances=MAX_INST)
    deployed = (1, 1)
    state = _backlog_state(ev.sim, deployed)
    cfgs = [(1, 0), (2, 1), (0, 3), (1, 0)]       # includes a duplicate
    rates = ev.grid_from(state, cfgs, FACTORS, deployed=deployed)
    assert rates.shape == (len(FACTORS), len(cfgs))
    np.testing.assert_array_equal(rates[:, 0], rates[:, 3])
    n0 = ev.n_evals
    assert n0 == 3 * len(FACTORS)                 # distinct cells only
    # repeat: fully cached, and a sub-sweep hits the same memo
    np.testing.assert_array_equal(
        ev.grid_from(state, cfgs, FACTORS, deployed=deployed), rates)
    sub = ev.grid_from(state, cfgs[:2], FACTORS[1:], deployed=deployed)
    np.testing.assert_array_equal(sub, rates[1:, :2])
    assert ev.n_evals == n0
    # a different warm state is a different memo key
    other = _backlog_state(ev.sim, deployed, upto=40)
    ev.grid_from(other, cfgs, FACTORS, deployed=deployed)
    assert ev.n_evals == 2 * n0
    # warm cells bit-match the simulator's own warm lane
    direct = ev.sim.qos(cfgs, workloads=FACTORS, state=state,
                        deployed=deployed).rates
    np.testing.assert_array_equal(rates, direct)


def test_evaluator_grid_from_warm_cache_is_lru_bounded():
    ev = PoolEvaluator(PROF, [FAST, SLOW], _workload(seed=8),
                       max_instances=MAX_INST)
    states = [PoolState(free=np.full(MAX_INST, 0.01 * (k + 1)), clock=0.0)
              for k in range(ev._warm_states + 1)]
    for s in states:
        ev.grid_from(s, [(1, 1)], (1.0,))
    assert len(ev._warm_cache) == ev._warm_states
    n0 = ev.n_evals
    ev.grid_from(states[-1], [(1, 1)], (1.0,))    # most recent: cached
    assert ev.n_evals == n0
    ev.grid_from(states[0], [(1, 1)], (1.0,))     # evicted: re-simulated
    assert ev.n_evals == n0 + 1


# --------------------------------------------------- rescale integration
def test_rescale_warm_state_scores_candidates_from_backlog():
    wl = _workload(seed=0, n=200, rate=120.0)
    ev = PoolEvaluator(PROF, [FAST, SLOW], wl, max_instances=MAX_INST)
    space = SearchSpace(bounds=(4, 4), prices=(1.0, 0.3))
    opt = RibbonOptimizer(space, qos_target=0.9)
    for _ in range(25):
        cfg = opt.ask()
        if cfg is None or opt.done:
            break
        opt.tell(cfg, ev(cfg))
    assert opt.best_config is not None
    deployed = opt.best_config
    state = _backlog_state(ev.sim, (2, 1), upto=80)

    event = rescale(opt, ev, budget=20, load_factors=(1.0, 1.5),
                    warm_state=state, deployed=deployed)
    assert event.warm_scored
    assert event.new_best is not None
    assert event.qos_by_load is not None
    # qos_by_load is the warm score of the winner, straight from the lanes
    for f, rate in event.qos_by_load.items():
        direct = ev.sim.qos([event.new_best], workloads=[f], state=state,
                            deployed=deployed).rates[0, 0]
        assert rate == direct


def test_rescale_without_warm_state_stays_cold():
    ev = PoolEvaluator(PROF, [FAST, SLOW], _workload(seed=9),
                       max_instances=MAX_INST)
    opt = RibbonOptimizer(SearchSpace(bounds=(4, 4), prices=(1.0, 0.3)),
                          qos_target=0.9)
    for _ in range(10):
        cfg = opt.ask()
        if cfg is None or opt.done:
            break
        opt.tell(cfg, ev(cfg))
    event = rescale(opt, ev, budget=10, load_factors=(1.0, 1.2))
    assert not event.warm_scored


def test_rescale_warm_state_requires_grid_from_evaluator():
    opt = RibbonOptimizer(SearchSpace(bounds=(3, 3), prices=(1.0, 0.3)),
                          qos_target=0.9)

    class GridOnly:
        def grid(self, configs, factors):
            return np.ones((len(factors), len(configs)))

    with pytest.raises(TypeError, match="grid_from"):
        rescale(opt, GridOnly(), budget=5, load_factors=(1.0,),
                warm_state=PoolState.idle(MAX_INST), deployed=(1, 1))
