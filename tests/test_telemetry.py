"""Device-resident telemetry plane: the three telemetry styles (twin-scan
finalize, in-carry grid accumulators, host numpy mirror) must agree bit for
bit, primary outputs must be bit-identical with telemetry off on every lane,
and the histogram estimators must stay within one log bucket of the exact
sample statistics."""

import numpy as np
import pytest

from repro.serving.instance import InstanceType, ModelProfile
from repro.serving.routing import RoutingPolicy, named_policy
from repro.serving.simulator import (PoolSimulator, PoolState,
                                     _qos_threshold_f32)
from repro.serving.telemetry import (BUCKET_EDGES, N_BUCKETS, Telemetry,
                                     bucket_index, from_arrays)
from repro.serving.workload import generate_workload

FAST = InstanceType("fast", price=1.0, flops=1e9, mem_bw=1e9, overhead=1e-3)
SLOW = InstanceType("slow", price=0.3, flops=2e8, mem_bw=5e8, overhead=2e-3)
PROF = ModelProfile("toy", flops_per_sample=1e6, act_bytes_per_sample=1e4,
                    weight_bytes=1e5, qos_latency=0.05)
MAX_INST = 8


def _sim(seed=0, n=300, rate=200.0):
    wl = generate_workload(seed, n, rate, median_batch=8.0, max_batch=32)
    return PoolSimulator(PROF, [FAST, SLOW], wl, max_instances=MAX_INST)


def _tel_fields(tel):
    return (tel.served, tel.miss, tel.busy_ms, tel.lat_hist, tel.wait_hist,
            tel.depth_sum, tel.depth_peak)


def assert_tel_equal(a: Telemetry, b: Telemetry):
    for x, y in zip(_tel_fields(a), _tel_fields(b)):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


CFGS = [(2, 1), (1, 0), (3, 3), (0, 2)]


# ------------------------------------------------------------ basic counters
@pytest.mark.parametrize("config", CFGS)
def test_served_counts_sum_to_n_queries(config):
    sim = _sim()
    tel = sim.qos(config, telemetry=True).telemetry
    assert int(tel.served.sum()) == sim.workload.n_queries
    assert tel.n == sim.workload.n_queries
    assert int(tel.lat_hist.sum()) == sim.workload.n_queries
    assert int(tel.wait_hist.sum()) == sim.workload.n_queries


def test_zero_config_serves_nothing():
    sim = _sim()
    tel = sim.qos((0, 0), telemetry=True).telemetry
    assert int(tel.served.sum()) == 0
    assert int(tel.lat_hist.sum()) == 0
    assert int(tel.depth_peak) == 0


def test_miss_counts_reconcile_with_qos_rate():
    """served - miss is exactly the device's QoS-pass count."""
    sim = _sim()
    for config in CFGS:
        r = sim.qos(config, telemetry=True)
        tel = r.telemetry
        passes = int(tel.served.sum() - tel.miss.sum())
        assert passes == round(float(r.rates) * sim.workload.n_queries)


def test_single_type_pool_attributes_everything_to_that_type():
    sim = _sim()
    tel = sim.qos((0, 2), telemetry=True).telemetry
    assert int(tel.served[0]) == 0
    assert int(tel.busy_ms[0]) == 0
    assert int(tel.served[1]) == sim.workload.n_queries


# ----------------------------------------------- on/off primary bit-identity
@pytest.mark.parametrize("config", CFGS)
def test_batch_lane_bit_identical_on_vs_off(config):
    sim = _sim()
    cfgs = [config, (1, 1), (2, 2)]
    off = sim.qos(cfgs)
    on = sim.qos(cfgs, telemetry=True)
    np.testing.assert_array_equal(np.asarray(off.rates), np.asarray(on.rates))
    np.testing.assert_array_equal(sim.simulate(cfgs).lat,
                                  sim.simulate(cfgs, telemetry=True).lat)


def test_grid_lane_bit_identical_on_vs_off():
    sim = _sim()
    cfgs = [(2, 1), (1, 2), (3, 0)]
    wls = [0.8, 1.0, 1.5]
    off = sim.qos(cfgs, workloads=wls)
    on = sim.qos(cfgs, workloads=wls, telemetry=True)
    np.testing.assert_array_equal(np.asarray(off.rates), np.asarray(on.rates))
    np.testing.assert_array_equal(sim.simulate(cfgs, workloads=wls).lat,
                                  sim.simulate(cfgs, workloads=wls,
                                               telemetry=True).lat)


def test_policy_lanes_bit_identical_on_vs_off():
    sim = _sim()
    cfgs = [(2, 1), (1, 1)]
    prices = [FAST.price, SLOW.price]
    stacked = RoutingPolicy.stack([named_policy(k, prices) for k in
                                   ("fcfs", "hedged")])
    for policy in (named_policy("hedged", prices), stacked):
        off = sim.qos(cfgs, policy=policy)
        on = sim.qos(cfgs, policy=policy, telemetry=True)
        np.testing.assert_array_equal(np.asarray(off.rates),
                                      np.asarray(on.rates))


def test_warm_lanes_bit_identical_on_vs_off_including_carry():
    sim = _sim()
    state = PoolState(free=np.full(MAX_INST, 0.4), clock=0.2)
    cfgs = [(2, 1), (1, 1), (0, 2)]
    off = sim.qos(cfgs, state=state, deployed=(2, 1))
    on = sim.qos(cfgs, state=state, deployed=(2, 1), telemetry=True)
    np.testing.assert_array_equal(np.asarray(off.rates), np.asarray(on.rates))
    for s_off, s_on in zip(np.atleast_1d(off.state), np.atleast_1d(on.state)):
        np.testing.assert_array_equal(s_off.free, s_on.free)
        assert s_off.clock == s_on.clock


def test_single_lane_bit_identical_on_vs_off():
    sim = _sim()
    for config in CFGS:
        np.testing.assert_array_equal(sim.simulate(config).lat,
                                      sim.simulate(config,
                                                   telemetry=True).lat)


# --------------------------------------------- cross-style bit-equivalence
@pytest.mark.parametrize("config", [(2, 1), (1, 2), (4, 0)])
def test_grid_cell_equals_batch_lane_telemetry(config):
    """The in-carry grid accumulators and the twin-scan finalize are two
    independent device implementations; a 1.0-factor grid cell must equal
    the batch lane bit for bit."""
    sim = _sim()
    batch = sim.qos([config, (1, 1)], telemetry=True).telemetry[0]
    grid = sim.qos([config, (1, 1)], workloads=[1.0],
                   telemetry=True).telemetry[0, 0]
    assert_tel_equal(batch, grid)


@pytest.mark.parametrize("config", [(2, 1), (3, 3)])
def test_host_mirror_equals_device_telemetry(config):
    """The numpy reference (segment trace -> from_arrays/queue_depth) must
    reproduce the device finalize bit for bit."""
    sim = _sim()
    device = sim.qos(config, telemetry=True).telemetry
    seg = sim.segment_from(sim.initial_state(), config, telemetry=True)
    assert_tel_equal(device, seg.telemetry)


def test_policy_batch_rows_equal_single_policy_telemetry():
    sim = _sim()
    pols = [named_policy(k, [FAST.price, SLOW.price])
            for k in ("fcfs", "hedged")]
    stacked = RoutingPolicy.stack(pols)
    cfgs = [(2, 1), (1, 1)]
    joint = sim.qos(cfgs, policy=stacked, telemetry=True).telemetry
    for p, pol in enumerate(pols):
        rows = sim.qos(cfgs, policy=pol, telemetry=True).telemetry
        for b in range(len(cfgs)):
            assert_tel_equal(joint[p, b], rows[b])


# ------------------------------------------------- chunked-segment merging
def test_window_slices_merge_to_one_shot_exactly():
    sim = _sim()
    seg = sim.segment_from(sim.initial_state(), (2, 1))
    full = sim.segment_telemetry(seg, (2, 1))
    n = sim.workload.n_queries
    for cuts in ([0, 100, n], [0, 1, 2, n], [0, 37, 38, 200, n]):
        acc = Telemetry.zeros(2)
        for lo, hi in zip(cuts[:-1], cuts[1:]):
            acc = acc + sim.segment_telemetry(seg, (2, 1), lo, hi)
        assert_tel_equal(acc, full)


def test_merge_rejects_shape_mismatch():
    with pytest.raises(ValueError, match="different shapes"):
        Telemetry.zeros(2).merge(Telemetry.zeros(3))


def test_chunked_streams_merge_to_concatenated_stream():
    """Serving a stream in two chunks through the carried state and merging
    the two segment telemetries equals the one-shot telemetry of the
    concatenated stream (integer accumulators + exact carry chaining)."""
    sim = _sim(n=240)
    seg = sim.segment_from(sim.initial_state(), (2, 1))
    k = 150
    first = sim.segment_telemetry(seg, (2, 1), 0, k)
    second = sim.segment_telemetry(seg, (2, 1), k, None)
    assert_tel_equal(first + second, sim.segment_telemetry(seg, (2, 1)))


# --------------------------------------------------- histogram percentiles
def test_bucket_edges_are_float32_exact_powers_of_two():
    assert N_BUCKETS == 32
    assert len(BUCKET_EDGES) == N_BUCKETS - 1
    ratios = BUCKET_EDGES[1:] / BUCKET_EDGES[:-1]
    np.testing.assert_array_equal(ratios, np.full(N_BUCKETS - 2, 2.0,
                                                  dtype=np.float32))


@pytest.mark.parametrize("pct", [50.0, 95.0, 99.0])
@pytest.mark.parametrize("config", [(2, 1), (1, 0), (3, 3)])
def test_percentile_within_one_bucket_of_exact(config, pct):
    """The nearest-rank histogram estimate must land in (or at the upper
    edge of) the bucket containing the exact sample percentile — i.e.
    within a factor-of-two bracket."""
    sim = _sim()
    tel = sim.qos(config, telemetry=True).telemetry
    lat = np.asarray(sim.simulate(config).lat, dtype=np.float32)
    exact = float(np.percentile(lat, pct, method="inverted_cdf"))
    est = tel.latency_percentile(pct)
    k_exact = int(bucket_index(np.float32(exact)))
    k_est = int(np.searchsorted(
        np.concatenate([BUCKET_EDGES, [np.float32(np.inf)]]), est))
    assert abs(k_est - k_exact) <= 1
    # The estimate is an upper edge: never below the exact percentile.
    assert est >= exact * (1.0 - 1e-6)


def test_percentile_monotone_in_pct():
    sim = _sim()
    tel = sim.qos((2, 1), telemetry=True).telemetry
    ps = [tel.latency_percentile(p) for p in (10, 50, 90, 99, 100)]
    assert all(a <= b for a, b in zip(ps, ps[1:]))


def test_percentile_requires_unbatched_lane():
    sim = _sim()
    tel = sim.qos([(2, 1), (1, 1)], telemetry=True).telemetry
    with pytest.raises(ValueError, match="unbatched"):
        tel.latency_percentile(99.0)
    assert tel[0].latency_percentile(99.0) > 0.0


def test_tail_latency_matches_telemetry_percentile():
    sim = _sim()
    tel = sim.qos((2, 1), telemetry=True).telemetry
    assert sim.tail_latency((2, 1), 99.0) == tel.latency_percentile(99.0)
    # warm + routed tails ride the same surface
    state = PoolState(free=np.full(MAX_INST, 0.3), clock=0.1)
    warm_tel = sim.qos((2, 1), state=state, deployed=(2, 1),
                       telemetry=True).telemetry
    assert (sim.tail_latency((2, 1), 95.0, state=state)
            == pytest.approx(warm_tel.latency_percentile(95.0)))


# ------------------------------------------------------- derived quantities
def test_utilization_bounded_and_zero_for_absent_types():
    sim = _sim()
    tel = sim.qos((2, 0), telemetry=True).telemetry
    span = float(sim.workload.arrivals[-1])
    util = tel.utilization((2, 0), span)
    assert util.shape == (2,)
    assert util[1] == 0.0
    assert 0.0 < util[0]


def test_from_arrays_matches_hand_counts():
    lat = np.array([0.01, 0.2, 0.0005], dtype=np.float32)
    wait = np.array([0.0, 0.1, 0.0], dtype=np.float32)
    svc = np.array([0.01, 0.1, 0.0005], dtype=np.float32)
    tslot = np.array([0, 1, 0])
    qos_t = _qos_threshold_f32(0.05)
    tel = from_arrays(lat, wait, svc, tslot, 2, qos_t,
                      depth=np.array([0, 1, 2]))
    np.testing.assert_array_equal(tel.served, [2, 1])
    np.testing.assert_array_equal(tel.miss, [0, 1])
    np.testing.assert_array_equal(tel.busy_ms, [10, 100])
    assert int(tel.depth_sum) == 3 and int(tel.depth_peak) == 2
    assert int(tel.lat_hist.sum()) == 3


def test_to_dict_is_json_safe_and_finite():
    import json

    sim = _sim()
    doc = sim.qos((2, 1), telemetry=True).telemetry.to_dict()
    rt = json.loads(json.dumps(doc))
    assert rt["p50"] <= rt["p95"] <= rt["p99"]
    assert sum(rt["served"]) == sim.workload.n_queries


# ----------------------------------------------------------- property sweep
def test_prop_all_lanes_bit_identical_and_counts_conserved():
    """Hypothesis (shim) sweep: across workload seeds/rates and pool mixes,
    telemetry-on never perturbs a primary output and served counts always
    sum to n_queries on batch, grid and policy lanes."""
    from hypothesis import given, settings
    from hypothesis import strategies as st

    @settings(max_examples=8)
    @given(st.integers(min_value=0, max_value=10_000),
           st.floats(min_value=60.0, max_value=600.0))
    def run(seed, rate):
        sim = _sim(seed=seed, n=120, rate=rate)
        cfgs = [(2, 1), (0, 1), (1, 3)]
        off = sim.qos(cfgs)
        on = sim.qos(cfgs, telemetry=True)
        np.testing.assert_array_equal(np.asarray(off.rates),
                                      np.asarray(on.rates))
        np.testing.assert_array_equal(
            np.asarray(on.telemetry.served.sum(axis=-1)), [120, 120, 120])
        gon = sim.qos(cfgs, workloads=[1.0, 1.3], telemetry=True)
        np.testing.assert_array_equal(
            np.asarray(gon.rates),
            np.asarray(sim.qos(cfgs, workloads=[1.0, 1.3]).rates))
        assert int(gon.telemetry.served.sum()) == 120 * 2 * 3
        pol = named_policy("hedged", [FAST.price, SLOW.price])
        pon = sim.qos(cfgs, policy=pol, telemetry=True)
        np.testing.assert_array_equal(
            np.asarray(pon.rates), np.asarray(sim.qos(cfgs, policy=pol).rates))
        np.testing.assert_array_equal(
            np.asarray(pon.telemetry.served.sum(axis=-1)), [120, 120, 120])

    run()
