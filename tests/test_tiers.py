"""Hybrid capacity tiers: hazard/price processes, the tier catalog, cold
starts priced through the warm lanes, and risk-adjusted BO costs.

Regression anchors:

* **hazard/price determinism** — `TierHazard.storms` and
  `SpotPriceProcess.events` are pure functions of (tier, seed): the
  absolute-axis timeline never moves, which is what makes restock
  *re-enter* (not reset) the hazard process.
* **cold-start bit-identity** — warm batched/grid lanes with ``warmup``
  match the sequential ``remap(..., warmup=...)`` + ``*_from`` path
  exactly, the same contract the un-warmed lanes already pin.
* **risk-adjusted costs** — `RibbonOptimizer(cost_penalties=...)` keeps
  the host prune mirror bit-identical to the device costs, renormalizes
  Eq. 2, and round-trips through `state_dict`.
* **registry coverage** — every event kind in the spec registry has an
  engine handler and a validation path; tier-scoped kinds reject bad
  tiers and fractions.
"""

import dataclasses

import numpy as np
import pytest

from repro.core import RibbonOptimizer
from repro.core.search_space import SearchSpace
from repro.scenario.engine import ScenarioEngine
from repro.scenario.spec import (EVENT_KIND_SPECS, EVENT_KINDS, EventSpec,
                                 PhaseSpec, ScenarioSpec, fuzz_kinds)
from repro.serving.instance import MODEL_PROFILES, InstanceType, ModelProfile
from repro.serving.simulator import PoolSimulator
from repro.serving.tiers import (TIER_NAMES, TIERS, SpotPriceProcess,
                                 TierCatalog, TierHazard, tiered_pool,
                                 tiered_variant)
from repro.serving.workload import generate_workload

FAST = InstanceType("fast", price=1.0, flops=1e9, mem_bw=1e9, overhead=1e-3)
SLOW = InstanceType("slow", price=0.3, flops=2e8, mem_bw=5e8, overhead=2e-3)
PROF = ModelProfile("toy", flops_per_sample=1e6, act_bytes_per_sample=1e4,
                    weight_bytes=1e5, qos_latency=0.05)
MAX_INST = 8


def _sim(types=None, wl=None):
    wl = wl or generate_workload(0, 150, 150.0, median_batch=8.0,
                                 max_batch=32)
    return PoolSimulator(PROF, types or [FAST, SLOW], wl,
                         max_instances=MAX_INST)


def _backlog_state(sim, deployed=(1, 1), upto=90):
    seg = sim.segment_from(sim.initial_state(), deployed)
    return seg.state_at(upto).rebased(float(sim.workload.arrivals[upto - 1]))


# ------------------------------------------------------- hazard processes
def test_tier_hazard_deterministic_and_bounded():
    h = TierHazard("spot", seed=3, n_phases=4)
    storms = h.storms()
    assert storms == TierHazard("spot", seed=3, n_phases=4).storms()
    assert len(storms) >= 1                    # storm guarantee
    phases = [p for p, _, _ in storms]
    assert phases == sorted(phases)
    assert len(set(phases)) == len(phases)     # at most one storm per phase
    for phase, at_frac, kill in storms:
        assert 0 <= phase < 3                  # final phase is storm-free
        assert 0.15 <= at_frac < 0.55
        assert 0.05 <= kill <= 0.95
    assert any(TierHazard("spot", seed=s, n_phases=4).storms() != storms
               for s in range(4, 10))          # seeds actually vary the draw


def test_tier_hazard_absolute_axis_never_resets():
    """The storm timeline is a pure function of (tier, seed): querying it
    again after a simulated restock returns the identical absolute-axis
    schedule — restocked capacity re-enters the same process."""
    h = TierHazard("spot", seed=11, n_phases=5)
    before = h.storms()
    for _ in range(3):                         # "restocks" between queries
        assert h.storms() == before
    # zero-rate tiers and degenerate horizons never storm
    assert TierHazard("on_demand", seed=11, n_phases=5).storms() == []
    assert TierHazard("spot", seed=11, n_phases=1).storms() == []


def test_spot_price_process_band_and_determinism():
    proc = SpotPriceProcess(seed=5)
    events = proc.events(6)
    assert events == SpotPriceProcess(seed=5).events(6)
    level = 1.0
    for phase, at_frac, factor in events:
        assert 0 <= phase < 5
        assert 0.3 <= at_frac <= 0.6
        assert factor > 0 and abs(factor - 1.0) >= 0.02
        level *= factor
        assert proc.band[0] - 1e-9 <= level <= proc.band[1] + 1e-9
    assert SpotPriceProcess(seed=6).events(6) != events


# ----------------------------------------------------------- tier catalog
def test_tier_catalog_indices_cold_starts_and_penalties():
    types = [FAST, tiered_variant(FAST, "spot"),
             tiered_variant(SLOW, "serverless")]
    cat = TierCatalog(types)
    assert cat.tiers == ("on_demand", "spot", "serverless")
    assert cat.tier_indices("spot") == (1,)
    assert cat.tier_indices("on_demand") == (0,)
    cold = cat.cold_starts(PROF)
    expect = [TIERS[t].cold_start_qos * PROF.qos_latency for t in cat.tiers]
    np.testing.assert_allclose(cold, expect)
    pen = cat.cost_penalties()
    assert all(p >= 0 for p in pen)
    # the spot type's interruption risk dominates every other premium
    assert pen[1] > pen[0] and pen[1] > pen[2]


def test_tier_catalog_rejects_unknown_tier():
    bad = dataclasses.replace(FAST, tier="preemptible")
    with pytest.raises(ValueError, match="preemptible"):
        TierCatalog([FAST, bad])


def test_tiered_variant_and_pool():
    spot = tiered_variant(FAST, "spot")
    assert spot.name == "fast:spot" and spot.tier == "spot"
    assert spot.price == pytest.approx(FAST.price
                                       * TIERS["spot"].price_factor)
    # profile efficiency keys on the base name, so tier variants inherit it
    prof = MODEL_PROFILES["mtwnd"]
    assert prof.eff("g4dn:spot") == prof.eff("g4dn")
    types, bounds = tiered_pool("mtwnd")
    assert len(types) == len(bounds) > 0
    assert len({t.name for t in types}) == len(types)
    TierCatalog(types)                         # every tier is registered


# ------------------------------------------------- cold starts in the sim
def test_remap_warmup_charges_added_slots_only():
    sim = _sim()
    state = _backlog_state(sim)
    now = float(state.clock) + 0.25
    w = np.array([0.3, 0.8])
    warm = state.remap((1, 1), (2, 2), now, warmup=w)
    plain = state.remap((1, 1), (2, 2), now)
    # survivors (slot 0 of each type) keep their carry, bit for bit
    assert warm.free[0] == plain.free[0]
    assert warm.free[2] == plain.free[2]
    # added slots boot cold: idle at now + their type's cold start
    assert warm.free[1] == now + 0.3
    assert warm.free[3] == now + 0.8
    # padding stays at now (inactive slots never serve)
    np.testing.assert_array_equal(warm.free[4:], np.full(MAX_INST - 4, now))
    with pytest.raises(ValueError, match="warmup"):
        state.remap((1, 1), (2, 2), now, warmup=np.array([0.3]))


def test_remap_batch_warmup_matches_sequential_remap():
    sim = _sim()
    state = _backlog_state(sim, deployed=(2, 1))
    now = float(state.clock)
    w = np.array([0.45, 0.1])
    cfgs = np.array([(0, 0), (4, 4), (1, 3), (2, 1), (3, 0)])
    batch = state.remap_batch((2, 1), cfgs, now, warmup=w)
    for i, cfg in enumerate(cfgs):
        seq = state.remap((2, 1), tuple(cfg), now, warmup=w)
        np.testing.assert_array_equal(batch[i], seq.free)
    with pytest.raises(ValueError, match="warmup"):
        state.remap_batch((2, 1), cfgs, now, warmup=np.zeros(3))


def test_warm_lanes_with_warmup_match_sequential_from():
    """Grid/batch lanes with a cold-start vector reproduce the sequential
    remap + warm single-config path bit for bit — the same identity the
    un-warmed lanes pin, now with added slots paying their tier's boot."""
    sim = _sim()
    state = _backlog_state(sim, deployed=(1, 2))
    w = np.array([0.3, 0.04])
    cfgs = np.array([(2, 2), (1, 2), (4, 0), (0, 3)])
    rates = sim.qos(cfgs, state=state, deployed=(1, 2), warmup=w).rates
    grid = sim.qos(cfgs, workloads=[1.0, 1.4], state=state, deployed=(1, 2),
                   warmup=w).rates
    for i, cfg in enumerate(cfgs):
        seq_state = state.remap((1, 2), tuple(cfg), float(state.clock),
                                warmup=w)
        seq_rate = float(sim.qos(tuple(cfg), state=seq_state).rates)
        assert rates[i] == seq_rate
        assert grid[0, i] == seq_rate
    # zero warmup is the legacy remap, bit for bit
    np.testing.assert_array_equal(
        sim.qos(cfgs, state=state, deployed=(1, 2),
                warmup=np.zeros(2)).rates,
        sim.qos(cfgs, state=state, deployed=(1, 2)).rates)


def test_cold_start_costs_qos_on_scale_up():
    """Scaling up out of a backlog with a large cold start cannot beat the
    same scale-up with instant boots: the added slots serve later."""
    sim = _sim()
    state = _backlog_state(sim, deployed=(1, 0), upto=60)
    cfgs = np.array([(4, 4)])
    instant = sim.qos(cfgs, state=state, deployed=(1, 0)).rates
    slow = sim.qos(cfgs, state=state, deployed=(1, 0),
                   warmup=np.array([2.0, 2.0])).rates
    assert slow[0] <= instant[0]


# ------------------------------------------------- risk-adjusted BO costs
def _space():
    return SearchSpace(bounds=(3, 3), prices=(1.0, 0.3))


def test_cost_penalties_shift_costs_and_keep_prune_mirror():
    space = _space()
    base = RibbonOptimizer(space, qos_target=0.9)
    opt = RibbonOptimizer(space, qos_target=0.9,
                          cost_penalties=(0.5, 0.05))
    expect = (space.costs(opt.lattice)
              + opt.lattice @ np.array([0.5, 0.05]))
    np.testing.assert_allclose(opt.lattice_costs, expect)
    # Eq. 2 renormalizes to the risk-adjusted max; the host prune mirror
    # sees the same costs the device mask uses
    assert opt._max_cost == pytest.approx(float(expect.max()))
    np.testing.assert_array_equal(opt.prune.costs, opt.lattice_costs)
    # no penalties → bit-identical legacy costs and normalizer
    np.testing.assert_array_equal(base.lattice_costs,
                                  space.costs(base.lattice))
    assert base._max_cost == space.max_cost


def test_cost_penalties_validated():
    with pytest.raises(ValueError):
        RibbonOptimizer(_space(), cost_penalties=(0.1,))
    with pytest.raises(ValueError):
        RibbonOptimizer(_space(), cost_penalties=(0.1, -0.2))


def test_cost_penalties_state_roundtrip():
    opt = RibbonOptimizer(_space(), qos_target=0.9,
                          cost_penalties=(0.25, 0.1))
    rng = np.random.default_rng(0)
    for _ in range(6):
        cfg = opt.ask()
        if cfg is None:
            break
        opt.tell(cfg, float(rng.uniform(0.7, 1.0)))
    clone = RibbonOptimizer(_space(), qos_target=0.9)
    clone.load_state_dict(opt.state_dict())
    assert clone.cost_penalties == opt.cost_penalties
    np.testing.assert_array_equal(clone.lattice_costs, opt.lattice_costs)
    assert clone._max_cost == opt._max_cost
    np.testing.assert_array_equal(clone.prune.costs, clone.lattice_costs)
    assert clone.best_config == opt.best_config


# --------------------------------------------- registry / spec validation
def test_every_registered_kind_has_an_engine_handler():
    """The loud-failure satellite: the engine dispatch table covers the
    registry (a mismatch raises at import, this pins the invariant)."""
    for kind in EVENT_KINDS:
        assert kind in ScenarioEngine._EVENT_HANDLERS
        assert hasattr(ScenarioEngine, ScenarioEngine._EVENT_HANDLERS[kind])
    assert set(fuzz_kinds(tiered=True)) == {
        k for k, spec in EVENT_KIND_SPECS.items() if spec.fuzz}
    assert fuzz_kinds() == ("cell_failure", "spot_preemption",
                            "price_change", "load_spike")


def _spec(events):
    return ScenarioSpec(name="t", phases=(PhaseSpec("a", 100),
                                          PhaseSpec("b", 100)),
                        events=tuple(events))


def test_event_spec_tier_validation():
    ok = _spec([EventSpec("preemption_storm", phase=0, at_frac=0.3,
                          tier="spot", factor=0.5),
                EventSpec("tier_outage", phase=0, tier="serverless"),
                EventSpec("price_spike", phase=0, tier="spot", factor=1.4)])
    assert ok.validate() is ok
    with pytest.raises(ValueError, match="tier"):
        _spec([EventSpec("preemption_storm", phase=0, factor=0.5)]).validate()
    with pytest.raises(ValueError, match="tier"):
        _spec([EventSpec("tier_outage", phase=0,
                         tier="preemptible")]).validate()
    with pytest.raises(ValueError, match="tier"):
        _spec([EventSpec("cell_failure", phase=0,
                         tier="spot")]).validate()
    with pytest.raises(ValueError, match="kill"):
        _spec([EventSpec("preemption_storm", phase=0, tier="spot",
                         factor=1.5)]).validate()
    with pytest.raises(ValueError, match="factor"):
        _spec([EventSpec("price_spike", phase=0, tier="spot",
                         factor=0.0)]).validate()
    with pytest.raises(ValueError, match="type_index"):
        _spec([EventSpec("cell_failure", phase=0,
                         type_index=-1)]).validate()
    assert set(TIER_NAMES) == set(TIERS)
