"""Batched evaluation engine: single/batched equivalence + ask_batch rules."""

import numpy as np
import pytest

from repro.core import RibbonOptimizer
from repro.core.search_space import SearchSpace
from repro.serving.instance import (InstanceType, ModelProfile,
                                    service_time_table)
from repro.serving.pool import PoolEvaluator
from repro.serving.simulator import PoolSimulator
from repro.serving.workload import generate_workload

FAST = InstanceType("fast", price=1.0, flops=1e9, mem_bw=1e9, overhead=1e-3)
SLOW = InstanceType("slow", price=0.3, flops=2e8, mem_bw=5e8, overhead=2e-3)
PROF = ModelProfile("toy", flops_per_sample=1e6, act_bytes_per_sample=1e4,
                    weight_bytes=1e5, qos_latency=0.05)

MAX_INST = 8


def _sim(seed=0, n=200, rate=120.0):
    wl = generate_workload(seed, n, rate, median_batch=8.0, max_batch=32)
    return PoolSimulator(PROF, [FAST, SLOW], wl, max_instances=MAX_INST)


# ------------------------------------------------------- simulator equivalence
def test_latencies_batch_matches_single_exactly():
    """Property: simulate(configs).lat[i] == simulate(configs[i]).lat bit-
    for-bit, over random configs including the empty and max-capacity
    pools."""
    sim = _sim()
    rng = np.random.default_rng(0)
    configs = rng.integers(0, 5, size=(30, 2))
    configs[0] = (0, 0)                       # empty pool
    configs[1] = (MAX_INST // 2, MAX_INST // 2)   # max-capacity padding
    configs[2] = (MAX_INST, 0)
    batch = sim.simulate(configs).lat
    assert batch.shape == (len(configs), sim.workload.n_queries)
    for i, cfg in enumerate(configs):
        single = sim.simulate(tuple(int(c) for c in cfg)).lat
        np.testing.assert_array_equal(batch[i], single)


def test_qos_rate_batch_matches_single():
    sim = _sim(seed=3, n=150, rate=200.0)
    rng = np.random.default_rng(1)
    configs = rng.integers(0, 4, size=(16, 2))
    configs[0] = (0, 0)
    rates = sim.qos(configs).rates
    for i, cfg in enumerate(configs):
        assert rates[i] == float(sim.qos(tuple(int(c) for c in cfg)).rates)


def test_batch_rejects_overflow_and_bad_shape():
    sim = _sim()
    with pytest.raises(ValueError):
        sim.simulate([[MAX_INST, MAX_INST]])          # exceeds padding
    with pytest.raises(ValueError):
        sim.simulate([[1, 1, 1]])                     # wrong n_types


def test_empty_batch():
    sim = _sim()
    out = sim.simulate(np.zeros((0, 2), dtype=np.int64)).lat
    assert out.shape == (0, sim.workload.n_queries)


# ------------------------------------------------------------ evaluator batch
def test_pool_evaluator_batch_consistent_with_call():
    wl = generate_workload(0, 150, 150.0, median_batch=8.0, max_batch=32)
    ev = PoolEvaluator(PROF, [FAST, SLOW], wl, max_instances=MAX_INST)
    configs = [(1, 0), (2, 1), (0, 3), (1, 0)]        # includes a duplicate
    rates = ev.batch(configs)
    assert rates[0] == rates[3]
    for cfg, r in zip(configs, rates):
        assert r == ev(cfg)
    # duplicate + cache hits: only 3 distinct sims counted
    assert ev.n_evals == 3


def test_service_time_table_cached():
    batches = np.array([1, 8, 32])
    a = service_time_table(PROF, [FAST, SLOW], batches)
    b = service_time_table(PROF, [FAST, SLOW], batches)
    assert a is b
    assert not a.flags.writeable
    c = service_time_table(PROF, [SLOW, FAST], batches)   # order matters
    assert c is not a


# ----------------------------------------------------------------- ask_batch
SPACE = SearchSpace(bounds=(6, 8), prices=(1.0, 0.35))


def _oracle(config):
    cap = float(np.dot((10.0, 3.0), np.asarray(config, dtype=np.float64)))
    return min(1.0, cap / 33.0)


def test_ask_batch_no_duplicates_sampled_or_pruned():
    opt = RibbonOptimizer(SPACE, qos_target=0.99)
    for _ in range(4):                         # build up sampled/pruned state
        cfg = opt.ask()
        opt.tell(cfg, _oracle(cfg))
    batch = opt.ask_batch(8)
    assert len(batch) == len(set(batch))
    for cfg in batch:
        idx = SPACE.index_of(cfg)
        assert not opt.sampled[idx]
        assert not opt.prune.mask[idx]


def test_ask_batch_q1_equals_ask():
    a = RibbonOptimizer(SPACE, qos_target=0.99)
    b = RibbonOptimizer(SPACE, qos_target=0.99)
    for _ in range(6):
        ca, cb = a.ask(), b.ask_batch(1)
        assert cb == [ca]
        a.tell(ca, _oracle(ca))
        b.tell(ca, _oracle(ca))


def test_ask_twice_does_not_advance_low_ei_streak():
    """Repeated ask without tell must not double-count the low-EI streak or
    trip `done` early (streak accounting lives in tell, keyed by config)."""
    opt = RibbonOptimizer(SPACE, qos_target=0.99, patience=1, ei_tol=1e9)
    for _ in range(5):                 # every EI is "low" with ei_tol=1e9 ...
        cfg = opt.ask()
        assert cfg is not None
        assert not opt.done            # ... yet asks alone never trip done
        assert opt._low_ei_streak == 0
    opt.tell(cfg, _oracle(cfg))
    cfg2 = opt.ask()                   # EI-selected (init start consumed)
    opt.tell(cfg2, _oracle(cfg2))
    assert opt._low_ei_streak == 1 and opt.done


def test_incremental_incumbent_matches_trace_recompute():
    from repro.core.objective import ribbon_objective
    opt = RibbonOptimizer(SPACE, qos_target=0.99)
    for _ in range(10):
        cfg = opt.ask()
        if cfg is None:
            break
        opt.tell(cfg, _oracle(cfg))
        recomputed = max(ribbon_objective(e.qos_rate, e.cost, opt.qos_target,
                                          SPACE.max_cost)
                         for e in opt.trace.evaluations)
        assert opt.best_objective_observed() == pytest.approx(recomputed)


def test_ask_batch_exhausts_cleanly():
    tiny = SearchSpace(bounds=(1, 1), prices=(1.0, 1.0))
    opt = RibbonOptimizer(tiny, qos_target=0.99, start=(0, 0))
    seen = set()
    while True:
        batch = opt.ask_batch(3)
        if not batch:
            break
        for cfg in batch:
            assert cfg not in seen
            seen.add(cfg)
            opt.tell(cfg, 0.0 if sum(cfg) == 0 else 0.992)
    assert opt.exhausted
    assert opt.ask() is None
