"""Fault-tolerance layer: checkpointing, failures, stragglers, autoscaling."""

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core import RibbonOptimizer
from repro.core.search_space import SearchSpace
from repro.serving import checkpoint
from repro.serving.autoscaler import LoadMonitor, rescale
from repro.serving.fault import (StragglerModel, fail_instances,
                                 recover_from_capacity_change,
                                 recover_from_failure, reprice,
                                 simulate_fcfs_hedged)
from repro.serving.instance import InstanceType, ModelProfile
from repro.serving.workload import Workload, generate_workload

# ----------------------------------------------------------- checkpointing


def test_checkpoint_roundtrip(tmp_path):
    state = {"a": jnp.arange(6).reshape(2, 3), "b": {"c": jnp.ones(4)},
             "d": jnp.asarray(3)}
    checkpoint.save(tmp_path, state, step=7)
    like = jax.tree.map(jnp.zeros_like, state)
    restored, step = checkpoint.restore(tmp_path, like)
    assert step == 7
    for l1, l2 in zip(jax.tree.leaves(state), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(l1), np.asarray(l2))


def test_checkpoint_keep_last_k(tmp_path):
    state = {"x": jnp.zeros(2)}
    for s in range(6):
        checkpoint.save(tmp_path, state, step=s, keep=2)
    steps = sorted(int(p.stem.split("_")[1])
                   for p in tmp_path.glob("step_*.npz"))
    assert steps == [4, 5]


def test_checkpoint_async(tmp_path):
    state = {"x": jnp.arange(10)}
    t = checkpoint.save(tmp_path, state, step=1, async_write=True)
    t.join()
    restored, step = checkpoint.restore(tmp_path, {"x": jnp.zeros(10, jnp.int32)})
    assert step == 1


def test_checkpoint_shape_mismatch_raises(tmp_path):
    checkpoint.save(tmp_path, {"x": jnp.zeros(3)}, step=0)
    with pytest.raises(ValueError):
        checkpoint.restore(tmp_path, {"x": jnp.zeros(5)})


def test_checkpoint_empty_dir(tmp_path):
    state, step = checkpoint.restore(tmp_path, {"x": jnp.zeros(1)})
    assert state is None and step is None


def test_checkpoint_restore_explicit_step(tmp_path):
    for s in (1, 3, 9):
        checkpoint.save(tmp_path, {"x": jnp.full(2, s)}, step=s, keep=5)
    assert checkpoint.latest_step(tmp_path) == 9
    state, step = checkpoint.restore(tmp_path,
                                     {"x": jnp.zeros(2, jnp.int32)}, step=3)
    assert step == 3
    np.testing.assert_array_equal(np.asarray(state["x"]), [3, 3])
    # the manifest rides along atomically with its payload
    assert (tmp_path / "step_0000000003.json").exists()


def test_ribbon_optimizer_checkpoint_roundtrip(tmp_path):
    space = SearchSpace(bounds=(4, 4), prices=(1.0, 0.4))
    opt = RibbonOptimizer(space)
    def oracle(c):
        return min(1.0, (3 * c[0] + c[1]) / 10.0)

    for _ in range(5):
        cfg = opt.ask()
        opt.tell(cfg, oracle(cfg))
    checkpoint.save(tmp_path, opt.state_dict(), step=5)
    # state_dict contains python scalars/lists — restore only array leaves
    restored, _ = checkpoint.restore(tmp_path, opt.state_dict())
    opt2 = RibbonOptimizer(space)
    opt2.load_state_dict(restored)
    assert opt2.best_config == opt.best_config
    assert opt2.ask() == opt.ask()


# ------------------------------------------------------------- failures


def monotone_oracle(caps, demand):
    caps = np.asarray(caps, float)
    def f(cfg):
        return min(1.0, float(np.dot(caps, np.asarray(cfg, float))) / demand)
    return f


def test_fail_instances():
    assert fail_instances((3, 2, 1), 0) == (2, 2, 1)
    assert fail_instances((0, 2, 1), 0) == (0, 2, 1)


def test_fail_instances_validates_arguments():
    """Losing more than is deployed clamps at zero, but an index outside
    the pool or a negative count is a caller bug and must raise."""
    with pytest.raises(ValueError, match="type_index"):
        fail_instances((3, 2), 2)
    with pytest.raises(ValueError, match="type_index"):
        fail_instances((3, 2), -1)
    with pytest.raises(ValueError, match="count"):
        fail_instances((3, 2), 0, count=-1)
    assert fail_instances((3, 2), 0, count=5) == (0, 2)


def test_recover_from_capacity_change_multi_type():
    """A correlated event (tier storm/outage) shrinks several types in one
    recovery; bad indices raise instead of silently resizing nothing."""
    space = SearchSpace(bounds=(5, 8), prices=(1.0, 0.3))
    oracle = monotone_oracle((10.0, 3.0), demand=31.0)
    opt = RibbonOptimizer(space, qos_target=0.99)
    for _ in range(30):
        cfg = opt.ask()
        if cfg is None or opt.done:
            break
        opt.tell(cfg, oracle(cfg))
    new_opt, event = recover_from_capacity_change(
        opt, oracle, {0: 2, 1: 3}, budget=30, kind="recover_storm")
    assert new_opt.space.bounds == (3, 5)
    assert event.kind == "recover_storm"
    best = new_opt.trace.best_feasible()
    assert best is not None and oracle(best.config) >= 0.99
    with pytest.raises(ValueError, match="type_index"):
        recover_from_capacity_change(opt, oracle, {5: 1})


def test_recover_from_failure_replays_history():
    space = SearchSpace(bounds=(5, 8), prices=(1.0, 0.3))
    oracle = monotone_oracle((10.0, 3.0), demand=31.0)
    opt = RibbonOptimizer(space, qos_target=0.99)
    for _ in range(30):
        cfg = opt.ask()
        if cfg is None or opt.done:
            break
        opt.tell(cfg, oracle(cfg))
    assert opt.best_config is not None

    new_opt, event = recover_from_failure(opt, oracle, failed_type=0,
                                          lost=2, budget=30)
    assert new_opt.space.bounds == (3, 8)
    best = new_opt.trace.best_feasible()
    assert best is not None
    # brute-force optimum of the reduced space
    lat = new_opt.space.enumerate()
    costs = new_opt.space.costs(lat)
    feas = [c for cfg2, c in zip(lat, costs) if oracle(tuple(cfg2)) >= 0.99]
    assert best.cost == pytest.approx(min(feas))
    # replay made the continued search cheap
    assert event.samples_used <= 30


def test_replay_from_transfers_only_fitting_real_history():
    space = SearchSpace(bounds=(5, 8), prices=(1.0, 0.3))
    oracle = monotone_oracle((10.0, 3.0), demand=31.0)
    opt = RibbonOptimizer(space, qos_target=0.99)
    for _ in range(12):
        cfg = opt.ask()
        if cfg is None:
            break
        opt.tell(cfg, oracle(cfg))
    small = SearchSpace(bounds=(3, 8), prices=(1.0, 0.3))
    new_opt = RibbonOptimizer(small, qos_target=0.99)
    n = new_opt.replay_from(opt)
    fitting = {e.config for e in opt.trace.evaluations
               if e.config[0] <= 3}
    assert n == len(fitting)
    assert new_opt.trace.n_samples == n
    # replaying again is a no-op (already sampled)
    assert new_opt.replay_from(opt) == 0


def test_pessimistic_replay_transfers_only_infeasible_history():
    """Pessimistic replay: evidence a pool *failed* survives harsher
    scoring conditions (transferred as estimates — GP mass + dominance
    pruning), evidence it passed does not — best_feasible stays empty
    until a fresh probe re-earns feasibility honestly."""
    space = SearchSpace(bounds=(5, 8), prices=(1.0, 0.3))
    oracle = monotone_oracle((10.0, 3.0), demand=31.0)
    opt = RibbonOptimizer(space, qos_target=0.99)
    for _ in range(20):
        cfg = opt.ask()
        if cfg is None or opt.done:
            break
        opt.tell(cfg, oracle(cfg))
    assert opt.trace.best_feasible() is not None

    new_opt = RibbonOptimizer(space, qos_target=0.99)
    n = new_opt.replay_from(opt, pessimistic=True)
    infeasible = {e.config for e in opt.trace.real if e.qos_rate < 0.99}
    assert n == len(infeasible)
    assert all(e.estimated for e in new_opt.trace.evaluations)
    assert new_opt.trace.best_feasible() is None
    # an honest re-score of the old incumbent wins it back
    best_cfg = opt.trace.best_feasible().config
    new_opt.tell(best_cfg, oracle(best_cfg))
    assert new_opt.trace.best_feasible().config == best_cfg


def test_recover_with_negative_lost_restocks_capacity():
    """Negative loss = restored capacity: bounds grow, history replays, and
    the search can reclaim configs that need the restored instances."""
    space = SearchSpace(bounds=(3, 8), prices=(1.0, 0.3))
    oracle = monotone_oracle((10.0, 3.0), demand=31.0)
    opt = RibbonOptimizer(space, qos_target=0.99)
    for _ in range(25):
        cfg = opt.ask()
        if cfg is None or opt.done:
            break
        opt.tell(cfg, oracle(cfg))
    new_opt, event = recover_from_failure(opt, oracle, failed_type=0,
                                          lost=-2, budget=30,
                                          kind="restock")
    assert new_opt.space.bounds == (5, 8)
    assert event.kind == "restock"
    best = new_opt.trace.best_feasible()
    assert best is not None
    # the enlarged space's optimum is at least as cheap as the reduced one's
    old_best = opt.trace.best_feasible()
    assert best.cost <= old_best.cost + 1e-9


def test_reprice_replays_history_without_new_evaluations():
    """QoS is price-independent: once the space is fully explored, a price
    change re-converges from the replayed record with zero new calls."""
    space = SearchSpace(bounds=(2, 2), prices=(1.0, 0.3))
    calls = {"n": 0}

    def oracle(cfg):
        calls["n"] += 1
        return min(1.0, (3.0 * cfg[0] + 1.0 * cfg[1]) / 5.0)

    opt = RibbonOptimizer(space, qos_target=0.99)
    for cfg2 in space.enumerate():
        opt.tell(tuple(int(c) for c in cfg2), oracle(tuple(cfg2)))
    before = calls["n"]
    new_prices = (0.2, 5.0)       # the cheap type became the expensive one
    new_opt, event = reprice(opt, new_prices, oracle, budget=20)
    assert calls["n"] == before   # memo-free, measurement-free re-search
    assert event.kind == "price_change"
    assert new_opt.space.prices == new_prices
    # brute-force optimum under the new prices
    lat = space.enumerate()
    feas = [(float(np.dot(new_prices, c)), tuple(int(v) for v in c))
            for c in lat if oracle(tuple(c)) >= 0.99]
    assert event.new_cost == pytest.approx(min(f[0] for f in feas))


def test_load_monitor_downshift_detects_slack():
    mon = LoadMonitor(qos_target=0.9)
    lat = np.full(100, 0.01)
    waits = np.concatenate([np.full(50, 0.01), np.zeros(50)])
    assert mon.downshift(lat, np.zeros(100), 0.02) is False   # no baseline
    mon.observe(lat, waits, qos_latency=0.02)                 # baseline 0.5
    assert mon.downshift(lat, np.zeros(100), 0.02) is True    # queue gone
    assert mon.downshift(lat, waits, 0.02) is False           # unchanged
    bad = np.full(100, 0.05)
    assert mon.downshift(bad, np.zeros(100), 0.02) is False   # QoS violated


# ------------------------------------------------------------ stragglers

FAST = InstanceType("fast", price=1.0, flops=1e9, mem_bw=1e9, overhead=1e-3)
PROF = ModelProfile("toy", flops_per_sample=1e6, act_bytes_per_sample=1e4,
                    weight_bytes=1e5, qos_latency=0.05)


def test_hedging_mitigates_straggler_tail():
    wl = generate_workload(0, 600, 150.0, median_batch=8, max_batch=32)
    strag = StragglerModel(slow_factor=8.0, afflicted=(0,))
    base = simulate_fcfs_hedged(wl, [FAST], (3,), PROF, straggler=strag,
                                hedge_threshold=None)
    hedged = simulate_fcfs_hedged(wl, [FAST], (3,), PROF, straggler=strag,
                                  hedge_threshold=0.01)
    assert np.percentile(hedged, 99) <= np.percentile(base, 99)
    # hedging targets the tail; the mean rate may trade away marginally
    # (a winning duplicate occupies the alternate instance)
    assert (np.mean(hedged <= PROF.qos_latency)
            >= np.mean(base <= PROF.qos_latency) - 0.02)


def _svc(batch):
    return float(FAST.latency(PROF, batch))


def _hedge_stream(arrivals, batches):
    return Workload(arrivals=np.asarray(arrivals, dtype=np.float64),
                    batches=np.asarray(batches, dtype=np.int64),
                    rate_qps=1.0)


def test_hedge_fires_and_wins_deterministically():
    """A hand-built 2-slot race: A occupies the straggling slot, B the
    healthy one; C queues on the straggler (it frees first), the hedge
    fires, and the healthy copy wins — C's latency is exactly the
    alternate path's."""
    s1, s32 = _svc(1), _svc(32)
    f = 10.0
    wl = _hedge_stream([0.0, 0.0, 0.0], [1, 32, 1])
    strag = StragglerModel(slow_factor=f, afflicted=(0,))
    base = simulate_fcfs_hedged(wl, [FAST], (2,), PROF, straggler=strag,
                                hedge_threshold=None)
    finish = 2 * f * s1                  # C queued behind A on the straggler
    alt_finish = s32 + s1                # C behind B on the healthy slot
    assert base[2] == pytest.approx(finish)
    h = 0.5 * min(f * s1, finish - alt_finish)
    hedged = simulate_fcfs_hedged(wl, [FAST], (2,), PROF, straggler=strag,
                                  hedge_threshold=h)
    assert hedged[2] == pytest.approx(alt_finish)
    np.testing.assert_allclose(hedged[:2], base[:2])   # A, B untouched


def test_hedge_cancellation_is_free():
    """After a winning hedge the original slot is released at its
    pre-dispatch free time: the next query starts on it immediately
    instead of queueing behind a cancelled copy."""
    s1, s32 = _svc(1), _svc(32)
    f = 10.0
    d_arr = f * s1 * 1.05                # just after the released slot idles
    wl = _hedge_stream([0.0, 0.0, 0.0, d_arr], [1, 32, 1, 1])
    strag = StragglerModel(slow_factor=f, afflicted=(0,))
    h = 0.5 * min(f * s1, 2 * f * s1 - (s32 + s1))
    hedged = simulate_fcfs_hedged(wl, [FAST], (2,), PROF, straggler=strag,
                                  hedge_threshold=h)
    # D serves with zero queue wait — pure (straggler-slowed) service time.
    # Were the cancellation not free, the slot would stay busy until
    # 2*f*s1 and D would queue.
    assert hedged[3] == pytest.approx(f * s1)


def test_hedge_skips_marginal_redispatch():
    """The hedge fires but the alternate copy would not beat the original
    by more than the threshold: the re-dispatch is skipped and the
    original (queued) copy serves."""
    s1, s32 = _svc(1), _svc(32)
    f = 10.0
    finish = 2 * f * s1
    alt_finish = s32 + s1
    h = (finish - alt_finish) + 1e-4     # alt wins, but not by > h
    assert f * s1 > h                    # the hedge itself still fires
    wl = _hedge_stream([0.0, 0.0, 0.0], [1, 32, 1])
    strag = StragglerModel(slow_factor=f, afflicted=(0,))
    hedged = simulate_fcfs_hedged(wl, [FAST], (2,), PROF, straggler=strag,
                                  hedge_threshold=h)
    assert hedged[2] == pytest.approx(finish)


# ------------------------------------------------------------ autoscaler


def test_load_monitor_detects_rate_drop():
    mon = LoadMonitor(qos_target=0.99)
    good = np.full(100, 0.01)
    waits = np.zeros(100)
    assert mon.observe(good, waits, qos_latency=0.02) is False  # baseline
    bad = np.full(100, 0.05)
    bad_waits = np.full(100, 0.01)
    assert mon.observe(bad, bad_waits, qos_latency=0.02) is True


def test_rescale_after_load_change():
    space = SearchSpace(bounds=(5, 8), prices=(1.0, 0.3))
    oracle1 = monotone_oracle((10.0, 3.0), demand=31.0)
    opt = RibbonOptimizer(space, qos_target=0.99)
    for _ in range(30):
        cfg = opt.ask()
        if cfg is None or opt.done:
            break
        opt.tell(cfg, oracle1(cfg))
    # load x1.5
    oracle2 = monotone_oracle((10.0, 3.0), demand=31.0 * 1.5)
    event = rescale(opt, oracle2, budget=40)
    assert event.new_best is not None
    assert oracle2(event.new_best) >= 0.99
    # heavier load costs more
    assert event.new_cost >= event.old_cost
