"""Fault-tolerance layer: checkpointing, failures, stragglers, autoscaling."""

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core import RibbonOptimizer
from repro.core.search_space import SearchSpace
from repro.serving import checkpoint
from repro.serving.autoscaler import LoadMonitor, rescale
from repro.serving.fault import (StragglerModel, fail_instances,
                                 recover_from_failure, simulate_fcfs_hedged)
from repro.serving.instance import InstanceType, ModelProfile
from repro.serving.workload import generate_workload

# ----------------------------------------------------------- checkpointing


def test_checkpoint_roundtrip(tmp_path):
    state = {"a": jnp.arange(6).reshape(2, 3), "b": {"c": jnp.ones(4)},
             "d": jnp.asarray(3)}
    checkpoint.save(tmp_path, state, step=7)
    like = jax.tree.map(jnp.zeros_like, state)
    restored, step = checkpoint.restore(tmp_path, like)
    assert step == 7
    for l1, l2 in zip(jax.tree.leaves(state), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(l1), np.asarray(l2))


def test_checkpoint_keep_last_k(tmp_path):
    state = {"x": jnp.zeros(2)}
    for s in range(6):
        checkpoint.save(tmp_path, state, step=s, keep=2)
    steps = sorted(int(p.stem.split("_")[1])
                   for p in tmp_path.glob("step_*.npz"))
    assert steps == [4, 5]


def test_checkpoint_async(tmp_path):
    state = {"x": jnp.arange(10)}
    t = checkpoint.save(tmp_path, state, step=1, async_write=True)
    t.join()
    restored, step = checkpoint.restore(tmp_path, {"x": jnp.zeros(10, jnp.int32)})
    assert step == 1


def test_checkpoint_shape_mismatch_raises(tmp_path):
    checkpoint.save(tmp_path, {"x": jnp.zeros(3)}, step=0)
    with pytest.raises(ValueError):
        checkpoint.restore(tmp_path, {"x": jnp.zeros(5)})


def test_checkpoint_empty_dir(tmp_path):
    state, step = checkpoint.restore(tmp_path, {"x": jnp.zeros(1)})
    assert state is None and step is None


def test_ribbon_optimizer_checkpoint_roundtrip(tmp_path):
    space = SearchSpace(bounds=(4, 4), prices=(1.0, 0.4))
    opt = RibbonOptimizer(space)
    def oracle(c):
        return min(1.0, (3 * c[0] + c[1]) / 10.0)

    for _ in range(5):
        cfg = opt.ask()
        opt.tell(cfg, oracle(cfg))
    checkpoint.save(tmp_path, opt.state_dict(), step=5)
    # state_dict contains python scalars/lists — restore only array leaves
    restored, _ = checkpoint.restore(tmp_path, opt.state_dict())
    opt2 = RibbonOptimizer(space)
    opt2.load_state_dict(restored)
    assert opt2.best_config == opt.best_config
    assert opt2.ask() == opt.ask()


# ------------------------------------------------------------- failures


def monotone_oracle(caps, demand):
    caps = np.asarray(caps, float)
    def f(cfg):
        return min(1.0, float(np.dot(caps, np.asarray(cfg, float))) / demand)
    return f


def test_fail_instances():
    assert fail_instances((3, 2, 1), 0) == (2, 2, 1)
    assert fail_instances((0, 2, 1), 0) == (0, 2, 1)


def test_recover_from_failure_replays_history():
    space = SearchSpace(bounds=(5, 8), prices=(1.0, 0.3))
    oracle = monotone_oracle((10.0, 3.0), demand=31.0)
    opt = RibbonOptimizer(space, qos_target=0.99)
    for _ in range(30):
        cfg = opt.ask()
        if cfg is None or opt.done:
            break
        opt.tell(cfg, oracle(cfg))
    assert opt.best_config is not None

    new_opt, event = recover_from_failure(opt, oracle, failed_type=0,
                                          lost=2, budget=30)
    assert new_opt.space.bounds == (3, 8)
    best = new_opt.trace.best_feasible()
    assert best is not None
    # brute-force optimum of the reduced space
    lat = new_opt.space.enumerate()
    costs = new_opt.space.costs(lat)
    feas = [c for cfg2, c in zip(lat, costs) if oracle(tuple(cfg2)) >= 0.99]
    assert best.cost == pytest.approx(min(feas))
    # replay made the continued search cheap
    assert event.samples_used <= 30


# ------------------------------------------------------------ stragglers

FAST = InstanceType("fast", price=1.0, flops=1e9, mem_bw=1e9, overhead=1e-3)
PROF = ModelProfile("toy", flops_per_sample=1e6, act_bytes_per_sample=1e4,
                    weight_bytes=1e5, qos_latency=0.05)


def test_hedging_mitigates_straggler_tail():
    wl = generate_workload(0, 400, 150.0, median_batch=8, max_batch=32)
    strag = StragglerModel(slow_factor=8.0, afflicted=(0,))
    base = simulate_fcfs_hedged(wl, [FAST], (3,), PROF, straggler=strag,
                                hedge_threshold=None)
    hedged = simulate_fcfs_hedged(wl, [FAST], (3,), PROF, straggler=strag,
                                  hedge_threshold=0.01)
    assert np.percentile(hedged, 99) <= np.percentile(base, 99)
    # hedging targets the tail; the mean rate may trade away marginally
    # (a winning duplicate occupies the alternate instance)
    assert (np.mean(hedged <= PROF.qos_latency)
            >= np.mean(base <= PROF.qos_latency) - 0.02)


# ------------------------------------------------------------ autoscaler


def test_load_monitor_detects_rate_drop():
    mon = LoadMonitor(qos_target=0.99)
    good = np.full(100, 0.01)
    waits = np.zeros(100)
    assert mon.observe(good, waits, qos_latency=0.02) is False  # baseline
    bad = np.full(100, 0.05)
    bad_waits = np.full(100, 0.01)
    assert mon.observe(bad, bad_waits, qos_latency=0.02) is True


def test_rescale_after_load_change():
    space = SearchSpace(bounds=(5, 8), prices=(1.0, 0.3))
    oracle1 = monotone_oracle((10.0, 3.0), demand=31.0)
    opt = RibbonOptimizer(space, qos_target=0.99)
    for _ in range(30):
        cfg = opt.ask()
        if cfg is None or opt.done:
            break
        opt.tell(cfg, oracle1(cfg))
    # load x1.5
    oracle2 = monotone_oracle((10.0, 3.0), demand=31.0 * 1.5)
    event = rescale(opt, oracle2, budget=40)
    assert event.new_best is not None
    assert oracle2(event.new_best) >= 0.99
    # heavier load costs more
    assert event.new_cost >= event.old_cost
