"""scripts/check_bench.py CLI behavior on a temp bench dir.

Regression under test: ``--schema-only`` must short-circuit ``--history``
*before* any history I/O — a schema-only sweep used to append trend rows
and print regression WARNs for thresholds it was told to skip.
"""

import importlib.util
import json
from pathlib import Path

REPO_ROOT = Path(__file__).resolve().parent.parent


def _load_check_bench():
    spec = importlib.util.spec_from_file_location(
        "check_bench", REPO_ROOT / "scripts" / "check_bench.py")
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    return mod


CB = _load_check_bench()


def _artifact(tmp_path, qos=0.5):
    doc = {
        "schema_version": CB.SCHEMA_VERSION,
        "bench": "scenarios",
        "episodes": {
            "ep": {"qos_rate": qos, "total_cost": 1.0,
                   "recovered_all_events": True, "violation_windows": 3},
        },
    }
    path = tmp_path / "BENCH_scenarios.json"
    path.write_text(json.dumps(doc))
    return path


def _prior_history(tmp_path):
    """A prior entry from a different commit whose qos_rate is far better —
    any history trend pass over the artifact below must WARN."""
    hist = tmp_path / "history.jsonl"
    hist.write_text(json.dumps({
        "commit": "0000000", "bench": "scenarios",
        "source": str(tmp_path / "BENCH_scenarios.json"),
        "metrics": {"ep.qos_rate": [1.0, "higher"]},
    }) + "\n")
    return hist


def _run(tmp_path, *flags, capsys=None):
    args = [str(_artifact(tmp_path)), "--bench-dir", str(tmp_path),
            "--history-file", str(tmp_path / "history.jsonl"), *flags]
    rc = CB.main(args)
    out = capsys.readouterr().out if capsys else ""
    return rc, out


def test_schema_only_history_does_no_history_io(tmp_path, capsys):
    rc, out = _run(tmp_path, "--schema-only", "--history", capsys=capsys)
    assert rc == 0
    assert not (tmp_path / "history.jsonl").exists()
    assert "WARN" not in out
    assert "history" not in out          # mode line must not advertise it


def test_schema_only_history_leaves_existing_log_untouched_and_silent(
        tmp_path, capsys):
    hist = _prior_history(tmp_path)
    before = hist.read_text()
    rc, out = _run(tmp_path, "--schema-only", "--history", capsys=capsys)
    assert rc == 0
    assert hist.read_text() == before    # no upsert, no rewrite
    assert "WARN" not in out             # no trend warnings in schema mode


def test_history_without_schema_only_still_warns_and_appends(tmp_path,
                                                             capsys):
    hist = _prior_history(tmp_path)
    rc, out = _run(tmp_path, "--history", capsys=capsys)
    assert rc == 0
    assert "WARN" in out and "ep.qos_rate" in out
    lines = [json.loads(ln) for ln in hist.read_text().splitlines()]
    assert len(lines) == 2               # prior row + this run's upsert
    assert "history" in out


def _batch_eval_doc():
    return {
        "schema_version": CB.SCHEMA_VERSION, "bench": "batch_eval",
        "n_queries": 1500,
        "results": [{"batch_size": 32, "wall_time_single_s": 1.0,
                     "wall_time_batched_s": 0.1, "speedup": 10.0}],
        "grid": {"n_queries": 1500, "n_devices": 1, "n_workloads": 4,
                 "batch_size": 32, "wall_time_sequential_s": 1.0,
                 "wall_time_grid_s": 0.5, "speedup": 2.0,
                 "bit_identical": True},
        "warm": {"batch_size": 32, "wall_time_sequential_s": 1.0,
                 "wall_time_batched_s": 0.2, "speedup": 5.0,
                 "bit_identical": True, "warm_idle_delta_mean": 0.01},
        "routing": {"batch_size": 8, "n_policies": 4,
                    "wall_time_sequential_s": 1.0,
                    "wall_time_joint_s": 0.2, "speedup": 5.0,
                    "bit_identical": True, "surge_factor": 1.6,
                    "qos_target": 0.99, "fcfs_min_cost": 3.0,
                    "routed_min_cost": 2.0},
        "telemetry": {"batch_size": 32, "n_queries": 1500,
                      "wall_time_off_s": 0.01, "wall_time_on_s": 0.0105,
                      "overhead": 1.05, "bit_identical": True,
                      "served_counts_by_lane": {"batch": True},
                      "served_counts_ok": True},
    }


def test_batch_eval_routing_and_grid_gates(tmp_path, capsys):
    path = tmp_path / "BENCH_batch_eval.json"
    path.write_text(json.dumps(_batch_eval_doc()))
    assert CB.main([str(path)]) == 0
    capsys.readouterr()
    # the reduced grid floor only applies to single-device measurements
    doc = _batch_eval_doc()
    doc["grid"]["n_devices"] = 8
    path.write_text(json.dumps(doc))
    assert CB.main([str(path)]) == 1
    assert "grid" in capsys.readouterr().out
    # a batch_eval artifact without a routing section is incomplete
    doc = _batch_eval_doc()
    del doc["routing"]
    path.write_text(json.dumps(doc))
    assert CB.main([str(path)]) == 1
    assert "routing" in capsys.readouterr().out
    # inverted economics: the routed pool must undercut FCFS at the surge
    doc = _batch_eval_doc()
    doc["routing"]["routed_min_cost"] = 3.5
    path.write_text(json.dumps(doc))
    assert CB.main([str(path)]) == 1
    assert "does not beat FCFS" in capsys.readouterr().out
    # joint dispatch speedup under the full-size floor
    doc = _batch_eval_doc()
    doc["routing"]["speedup"] = 2.0
    path.write_text(json.dumps(doc))
    assert CB.main([str(path)]) == 1
    assert "joint speedup" in capsys.readouterr().out


def test_batch_eval_telemetry_gates(tmp_path, capsys):
    path = tmp_path / "BENCH_batch_eval.json"
    # a batch_eval artifact without a telemetry section is incomplete
    doc = _batch_eval_doc()
    del doc["telemetry"]
    path.write_text(json.dumps(doc))
    assert CB.main([str(path)]) == 1
    assert "telemetry" in capsys.readouterr().out
    # overhead over the full-size ceiling fails
    doc = _batch_eval_doc()
    doc["telemetry"]["overhead"] = 1.2
    path.write_text(json.dumps(doc))
    assert CB.main([str(path)]) == 1
    assert "telemetry-on overhead" in capsys.readouterr().out
    # ...but the same overhead passes on a smoke (shrunken) artifact
    doc["n_queries"] = 400
    path.write_text(json.dumps(doc))
    assert CB.main([str(path)]) == 0
    capsys.readouterr()
    # primary-output divergence and count-conservation failures are fatal
    doc = _batch_eval_doc()
    doc["telemetry"]["bit_identical"] = False
    path.write_text(json.dumps(doc))
    assert CB.main([str(path)]) == 1
    assert "diverge" in capsys.readouterr().out
    doc = _batch_eval_doc()
    doc["telemetry"]["served_counts_ok"] = False
    doc["telemetry"]["served_counts_by_lane"] = {"batch": True, "grid": False}
    path.write_text(json.dumps(doc))
    assert CB.main([str(path)]) == 1
    assert "grid" in capsys.readouterr().out
    # telemetry_overhead participates in the trend metrics, lower = better
    metrics = CB.trend_metrics(_batch_eval_doc())
    assert metrics["telemetry_overhead"] == (1.05, "lower")


def test_schema_only_skips_kind_gates_but_validates_schema(tmp_path,
                                                           capsys):
    # warm_idle_delta gates etc. are kind checks: skipped in schema mode
    path = tmp_path / "BENCH_scenarios.json"
    path.write_text(json.dumps({
        "schema_version": CB.SCHEMA_VERSION, "bench": "scenarios",
        "episodes": {"flash-crowd": {"recovered_all_events": False}},
    }))
    assert CB.main([str(path), "--schema-only"]) == 0
    capsys.readouterr()
    assert CB.main([str(path)]) == 1     # gates fire without --schema-only
    out = capsys.readouterr().out
    assert "did not recover" in out
    assert "warm_idle_delta_total" in out
    # a schema violation still fails schema-only mode
    bad = tmp_path / "BENCH_bad.json"
    bad.write_text(json.dumps({"schema_version": CB.SCHEMA_VERSION,
                               "bench": "x", "v": float("inf")}))
    assert CB.main([str(bad), "--schema-only"]) == 1


def _stream_doc(n=1_000_000):
    return {
        "schema_version": CB.SCHEMA_VERSION, "bench": "stream",
        "model": "mtwnd", "config": [2, 3, 3], "n_queries": n,
        "stream": {"n_queries": n, "chunk": 4096, "elapsed_s": 0.5,
                   "qps": 2_000_000.0, "qos_rate": 0.98, "rebases": 0},
        "memory": {"n_small": n // 4, "n_large": n, "peak_small_bytes": 52552,
                   "peak_large_bytes": 52552, "ratio": 1.0},
        "bit_identical": {"n_queries": 1500, "streamed_rate": 0.98,
                          "monolithic_rate": 0.98, "ok": True},
        "day": {"episode": "diurnal-day", "total_queries": n,
                "qos_rate": 0.995, "total_cost": 1.4, "completed": True},
    }


def test_stream_gates(tmp_path, capsys):
    path = tmp_path / "BENCH_stream.json"
    path.write_text(json.dumps(_stream_doc()))
    assert CB.main([str(path)]) == 0
    capsys.readouterr()
    # throughput below the full floor fails...
    doc = _stream_doc()
    doc["stream"]["qps"] = 50_000.0
    path.write_text(json.dumps(doc))
    assert CB.main([str(path)]) == 1
    assert "throughput" in capsys.readouterr().out
    # ...but passes at smoke scale, where the reduced floor applies
    doc["n_queries"] = doc["stream"]["n_queries"] = 20_000
    doc["day"]["total_queries"] = 10_000
    path.write_text(json.dumps(doc))
    assert CB.main([str(path)]) == 0
    capsys.readouterr()
    # a growing memory peak breaks the constant-memory claim
    doc = _stream_doc()
    doc["memory"]["ratio"] = 1.5
    doc["memory"]["peak_large_bytes"] = 78828
    path.write_text(json.dumps(doc))
    assert CB.main([str(path)]) == 1
    assert "constant-memory" in capsys.readouterr().out
    # streamed rate diverging from the monolithic reference is fatal
    doc = _stream_doc()
    doc["bit_identical"]["ok"] = False
    path.write_text(json.dumps(doc))
    assert CB.main([str(path)]) == 1
    assert "monolithic" in capsys.readouterr().out
    # a full-size run must cover the whole day episode
    doc = _stream_doc()
    doc["day"]["total_queries"] = 500_000
    path.write_text(json.dumps(doc))
    assert CB.main([str(path)]) == 1
    assert "day episode" in capsys.readouterr().out
    # missing sections are incomplete artifacts
    doc = _stream_doc()
    del doc["memory"]
    path.write_text(json.dumps(doc))
    assert CB.main([str(path)]) == 1
    assert "memory" in capsys.readouterr().out
    # stream throughput participates in the trend metrics
    metrics = CB.trend_metrics(_stream_doc())
    assert metrics["stream_qps"] == (2_000_000.0, "higher")
    assert metrics["stream_mem_ratio"] == (1.0, "lower")
