"""Request-size buckets and the exact Mélange solver.

Contracts under test:

* a 1-bucket :class:`BucketedWorkloadSpec` with unit scales reduces
  *bit-exactly* to the legacy scalar path on every lane — realized
  stream, cold ``qos()``, warm ``segment_from``, the stacked grid, and
  the streaming simulator;
* multi-bucket specs validate their rate-matrix shape and rate budget,
  annotate every query with an in-range bucket id, and actually move
  QoS when the buckets scale work;
* ``solve_bucketed`` is exact: the MILP and the pure-python branch and
  bound agree, the degenerate 1-bucket/1-type instance reproduces the
  simulator's exhaustive optimum, and the heterogeneous optimum never
  costs more than any homogeneous allocation;
* a mislabeled ``batch_dist`` spec still recovers: the engine's drift
  belief comes from measured waits (``SimulatorPlane.infer_dist``), not
  from the phase label.
"""

import dataclasses

import numpy as np
import pytest

from repro.core.baselines import solve_bucketed
from repro.core.search_space import SearchSpace
from repro.scenario import (PhaseSpec, ScenarioEngine, ScenarioSpec,
                            SimulatorPlane, build_episode)
from repro.scenario.planes import _prefix
from repro.serving.instance import (InstanceType, ModelProfile,
                                    measured_throughputs, service_table_for)
from repro.serving.pool import BUCKET_DIST_MIXES, PoolEvaluator
from repro.serving.simulator import PoolSimulator, StreamingSimulator
from repro.serving.workload import BucketedWorkloadSpec, WorkloadSpec

FAST = InstanceType("fast", price=1.0, flops=1e9, mem_bw=1e9, overhead=1e-3)
SLOW = InstanceType("slow", price=0.3, flops=2e8, mem_bw=5e8, overhead=2e-3)
PROF = ModelProfile("toy", flops_per_sample=1e6, act_bytes_per_sample=1e4,
                    weight_bytes=1e5, qos_latency=0.05)


def _spec(seed=0, rate=120.0):
    return WorkloadSpec(seed=seed, rate_qps=rate, chunk=256,
                        median_batch=8.0, mean_batch=10.0, std_batch=4.0,
                        max_batch=32)


def _two_buckets(spec, heavy=2.5):
    """1 input scale x 2 output scales, rate split evenly."""
    half = spec.rate_qps / 2.0
    return BucketedWorkloadSpec(base=spec, rates=((half, half),),
                                input_scales=(1.0,),
                                output_scales=(1.0, heavy))


# --------------------------------------------------- 1-bucket reduction
def test_unit_bucket_stream_bit_identical_to_scalar():
    spec = _spec()
    b1 = BucketedWorkloadSpec(base=spec, rates=((spec.rate_qps,),))
    wl_s, wl_b = spec.realize(400), b1.realize(400)
    assert np.array_equal(wl_s.arrivals, wl_b.arrivals)
    assert np.array_equal(wl_s.batches, wl_b.batches)
    assert wl_b.bucket_of is not None
    assert np.array_equal(np.asarray(wl_b.bucket_of), np.zeros(400, int))


def test_unit_bucket_qos_bit_identical_on_all_lanes():
    spec = _spec()
    b1 = BucketedWorkloadSpec(base=spec, rates=((spec.rate_qps,),))
    wl_s, wl_b = spec.realize(300), b1.realize(300)
    sim_s = PoolSimulator(PROF, [FAST, SLOW], wl_s, max_instances=8)
    sim_b = PoolSimulator(PROF, [FAST, SLOW], wl_b, max_instances=8)
    cfg = (2, 1)
    # cold lane
    r_s, r_b = sim_s.qos(cfg), sim_b.qos(cfg)
    assert float(r_s.rates) == float(r_b.rates)
    # warm lane: idle carry reproduces the cold bits, bucketed or not
    seg_s = sim_s.segment_from(sim_s.initial_state(), cfg)
    seg_b = sim_b.segment_from(sim_b.initial_state(), cfg)
    assert np.array_equal(seg_s.lat, seg_b.lat)
    assert np.array_equal(seg_s.waits, seg_b.waits)
    # grid lane: the stacked-table axis sees identical service tables
    grid_s = sim_s.qos([cfg, (1, 2)], workloads=[1.0, 1.3]).rates
    grid_b = sim_b.qos([cfg, (1, 2)], workloads=[1.0, 1.3]).rates
    assert np.array_equal(np.asarray(grid_s), np.asarray(grid_b))


def test_unit_bucket_streaming_bit_identical():
    spec = _spec()
    b1 = BucketedWorkloadSpec(base=spec, rates=((spec.rate_qps,),))
    st_s = StreamingSimulator(PROF, [FAST, SLOW], spec, max_instances=8)
    st_b = StreamingSimulator(PROF, [FAST, SLOW], b1, max_instances=8)
    r_s = st_s.qos((2, 1), n_queries=512)
    r_b = st_b.qos((2, 1), n_queries=512)
    assert float(r_s.rate) == float(r_b.rate)


# ------------------------------------------------------- bucketed specs
def test_bucketed_spec_validation():
    spec = _spec()
    with pytest.raises(ValueError):     # wrong column count
        BucketedWorkloadSpec(base=spec, rates=((60.0,), (60.0,)),
                             input_scales=(1.0, 1.0),
                             output_scales=(1.0, 2.5))
    with pytest.raises(ValueError):     # rates don't sum to base rate
        BucketedWorkloadSpec(base=spec, rates=((10.0, 10.0),),
                             input_scales=(1.0,),
                             output_scales=(1.0, 2.5))


def test_multi_bucket_annotations_and_qos_shift():
    spec = _spec()
    bspec = _two_buckets(spec, heavy=6.0)
    wl = bspec.realize(400)
    ids = np.asarray(wl.bucket_of)
    assert set(np.unique(ids)) <= {0, 1}
    assert 0 < ids.mean() < 1          # both buckets actually drawn
    # heavy output bucket inflates service times -> QoS drops vs scalar
    base = PoolSimulator(PROF, [FAST, SLOW], spec.realize(400),
                         max_instances=8).qos((1, 1))
    buck = PoolSimulator(PROF, [FAST, SLOW], wl, max_instances=8).qos((1, 1))
    assert float(buck.rates) < float(base.rates)
    # service table reflects the per-query bucket annotation
    tab = service_table_for(PROF, [FAST, SLOW], wl)
    assert tab.shape == (2, 400)


def test_measured_throughputs_shape_and_ordering():
    spec = _spec()
    wl = _two_buckets(spec, heavy=6.0).realize(400)
    tputs = measured_throughputs(PROF, [FAST, SLOW], wl)
    assert tputs.shape == (2, 2)
    assert (tputs > 0).all()
    # the heavy bucket sustains strictly fewer queries/s on every type
    assert (tputs[:, 1] < tputs[:, 0]).all()


# --------------------------------------------------------- exact solver
def test_solve_bucketed_enumerate_is_exact_and_feasible():
    rates = [40.0, 20.0]
    tputs = [[30.0, 5.0],      # cheap type, slow on heavy bucket
             [25.0, 20.0]]     # pricey type, good at heavy bucket
    prices = [1.0, 1.8]
    sol = solve_bucketed(rates, tputs, prices, slice_factor=4,
                         method="enumerate")
    assert sol.method == "enumerate"
    # assignment rows are simplex points quantized to 1/slice_factor
    for row in sol.assignment:
        assert abs(sum(row) - 1.0) < 1e-9
        for frac in row:
            assert abs(frac * 4 - round(frac * 4)) < 1e-9
    # bought capacity covers the demanded instance-time
    for t in range(2):
        assert sol.config[t] >= sol.loads[t] - 1e-9
    assert sol.cost == pytest.approx(
        sum(p * c for p, c in zip(prices, sol.config)))


def test_solve_bucketed_milp_matches_enumerate():
    pytest.importorskip("scipy.optimize")
    rates = [40.0, 20.0, 8.0]
    tputs = [[30.0, 5.0, 12.0],
             [25.0, 20.0, 6.0],
             [10.0, 10.0, 10.0]]
    prices = [1.0, 1.8, 0.9]
    a = solve_bucketed(rates, tputs, prices, slice_factor=4, method="milp")
    b = solve_bucketed(rates, tputs, prices, slice_factor=4,
                       method="enumerate")
    assert a.cost == pytest.approx(b.cost)
    assert a.config == b.config or a.cost == pytest.approx(b.cost)


def test_solve_bucketed_beats_homogeneous():
    rates = np.array([40.0, 20.0])
    tputs = np.array([[30.0, 5.0], [25.0, 20.0]])
    prices = np.array([1.0, 1.8])
    sol = solve_bucketed(rates, tputs, prices, slice_factor=8)
    for t in range(2):
        homo = prices[t] * np.ceil((rates / tputs[t]).sum())
        assert sol.cost <= homo + 1e-9
    # the mixed pool is strictly cheaper than either homogeneous one here
    assert sol.cost < min(prices[t] * np.ceil((rates / tputs[t]).sum())
                          for t in range(2))


def test_solve_bucketed_degenerate_matches_exhaustive():
    """1 bucket + 1 type + throughput calibrated from the simulator's own
    optimum: the ILP reproduces PoolEvaluator.exhaustive exactly."""
    spec = _spec(rate=150.0)
    wl = spec.realize(300)
    ev = PoolEvaluator(PROF, [FAST], wl, max_instances=6)
    space = SearchSpace(bounds=(6,), prices=(FAST.price,))
    best_cfg, best_cost, _ = ev.exhaustive(space, qos_target=0.95)
    n_star = int(best_cfg[0])
    assert n_star >= 1
    # one instance sustains rate/n* qps at the QoS knee by construction
    sol = solve_bucketed([spec.rate_qps], [[spec.rate_qps / n_star]],
                         [FAST.price], slice_factor=1, bounds=(6,))
    assert sol.config == (n_star,)
    assert sol.cost == pytest.approx(best_cost)


def test_solve_bucketed_rejects_unservable_and_infeasible():
    with pytest.raises(ValueError):    # nobody can serve bucket 1
        solve_bucketed([10.0, 5.0], [[20.0, 0.0]], [1.0])
    with pytest.raises(ValueError):    # bounds too tight for the load
        solve_bucketed([100.0], [[10.0]], [1.0], bounds=(2,),
                       method="enumerate")


# -------------------------------------------- drift from measured waits
class _MislabeledPlane(SimulatorPlane):
    """Serves Gaussian-batch traffic no matter what the spec label says —
    the episode's ``batch_dist`` annotations are all lies."""

    def phase_stream(self, dist, n, factor):
        return _prefix(self.workloads["gaussian"].scaled(factor), n)


def _dist_workloads(n=300, seed=0, rate=120.0):
    return {d: WorkloadSpec(seed=seed, rate_qps=rate, median_batch=8.0,
                            mean_batch=10.0, std_batch=4.0, max_batch=32,
                            batch_dist=d).realize(n)
            for d in ("lognormal", "gaussian")}


def test_mislabeled_batch_dist_recovers_from_measured_waits():
    wls = _dist_workloads()
    plane = _MislabeledPlane(PROF, [FAST, SLOW], wls, max_instances=8)
    spec = ScenarioSpec(
        name="mislabeled", qos_target=0.9, window=100, init_budget=20,
        phases=(PhaseSpec("lied", 300, 1.0, batch_dist="lognormal"),))
    rep = ScenarioEngine(spec, plane, SearchSpace(bounds=(4, 4),
                                                  prices=(1.0, 0.3)),
                         allow_downscale=False).run()
    # the belief flipped off the (wrong) spec label using only residuals
    ests = [w.dist_est for w in rep.windows]
    assert "gaussian" in ests
    assert "lognormal" not in ests
    assert rep.phases[0].qos_rate > 0.0


def test_honest_labels_estimate_matches_spec():
    wls = _dist_workloads()
    plane = SimulatorPlane(PROF, [FAST, SLOW], wls, max_instances=8)
    spec = ScenarioSpec(
        name="honest", qos_target=0.9, window=100, init_budget=20,
        phases=(PhaseSpec("ln", 300, 1.0, batch_dist="lognormal"),
                PhaseSpec("ga", 300, 1.0, batch_dist="gaussian")))
    rep = ScenarioEngine(spec, plane, SearchSpace(bounds=(4, 4),
                                                  prices=(1.0, 0.3)),
                         allow_downscale=False).run()
    n_ph = len(rep.windows) // 2
    assert all(w.dist_est in (None, "lognormal")
               for w in rep.windows[:n_ph])
    assert all(w.dist_est in (None, "gaussian")
               for w in rep.windows[n_ph:])


# ------------------------------------------------- bucketed drift episode
def test_dist_drift_bucketed_episode_runs_with_bucket_waits():
    spec = build_episode("dist-drift-bucketed", n=200, window=50)
    assert spec.validate() is spec
    base = _spec(rate=120.0)
    wls = {}
    for dist in ("bucketed-small", "bucketed-large"):
        mix = BUCKET_DIST_MIXES[dist]
        w = np.asarray(mix["weights"], dtype=np.float64)
        wls[dist] = BucketedWorkloadSpec(
            base=base, rates=tuple(tuple(base.rate_qps * x for x in row)
                                   for row in w / w.sum()),
            input_scales=mix["input_scales"],
            output_scales=mix["output_scales"]).realize(200)
    plane = SimulatorPlane(PROF, [FAST, SLOW], wls, max_instances=8)
    ep = dataclasses.replace(spec, qos_target=0.9)
    rep = ScenarioEngine(ep, plane, SearchSpace(bounds=(4, 4),
                                                prices=(1.0, 0.3)),
                         allow_downscale=False).run()
    assert len(rep.phases) == 3
    # per-bucket measured waits ride every window stat
    assert all(len(w.bucket_waits) == 4 for w in rep.windows)
    for w in rep.windows:
        assert all(np.isfinite(x) or np.isnan(x) for x in w.bucket_waits)
