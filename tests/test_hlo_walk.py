"""Trip-count-aware HLO accounting: validated against known-flop programs."""

import jax
import jax.numpy as jnp
import pytest

from repro.roofline.hlo_walk import analyze, parse_module


def _compile_text(f, *args):
    return jax.jit(f).lower(*args).compile().as_text()


def test_scan_flops_scale_with_trip_count():
    def f(x, w):
        def body(h, w_l):
            return h @ w_l, None
        h, _ = jax.lax.scan(body, x, w)
        return h
    x = jax.ShapeDtypeStruct((64, 128), jnp.float32)
    for n_layers in (2, 8):
        w = jax.ShapeDtypeStruct((n_layers, 128, 128), jnp.float32)
        acc = analyze(_compile_text(f, x, w))
        assert acc.flops == pytest.approx(n_layers * 2 * 64 * 128 * 128,
                                          rel=1e-6)


def test_nested_scan_flops():
    def g(x, w):
        def outer(h, w_o):
            def inner(h2, w_i):
                return h2 @ w_i, None
            h, _ = jax.lax.scan(inner, h, w_o)
            return h, None
        h, _ = jax.lax.scan(outer, x, w)
        return h
    x = jax.ShapeDtypeStruct((32, 64), jnp.float32)
    w = jax.ShapeDtypeStruct((3, 5, 64, 64), jnp.float32)
    acc = analyze(_compile_text(g, x, w))
    assert acc.flops == pytest.approx(15 * 2 * 32 * 64 * 64, rel=1e-6)


def test_plain_matmul_flops_and_bytes():
    def f(a, b):
        return a @ b
    a = jax.ShapeDtypeStruct((256, 512), jnp.float32)
    b = jax.ShapeDtypeStruct((512, 128), jnp.float32)
    acc = analyze(_compile_text(f, a, b))
    assert acc.flops == pytest.approx(2 * 256 * 512 * 128, rel=1e-6)
    # read a + b, write out (within 2x for layout copies)
    expect = 4 * (256 * 512 + 512 * 128 + 256 * 128)
    assert expect <= acc.hbm_bytes <= 3 * expect


def test_module_parsing_handles_tuple_types():
    """Tuple results with /*index=N*/ comments must parse (regression)."""
    def f(x):
        def body(c, _):
            a, b, d, e, g, h = c
            return (a + 1, b * 2.0, d, e, g, h @ h), None
        init = (jnp.int32(0), x[0, 0], x, x[0], x[:, 0], x)
        out, _ = jax.lax.scan(body, init, None, length=7)
        return out[5]
    x = jnp.ones((8, 8))
    txt = jax.jit(f).lower(x).compile().as_text()
    comps = parse_module(txt)
    whiles = [i for c in comps.values() for i in c.instrs if i.op == "while"]
    assert whiles, "while instruction must parse despite tuple types"
    acc = analyze(txt)
    assert acc.flops == pytest.approx(7 * 2 * 8 * 8 * 8, rel=1e-6)
