"""Pallas kernel validation: shape/dtype sweeps vs the pure-jnp oracles
(interpret=True executes the kernel bodies on CPU)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref

TOLS = {jnp.float32: dict(rtol=2e-3, atol=2e-3),
        jnp.bfloat16: dict(rtol=2e-2, atol=2e-2)}


def _tols(dtype):
    return TOLS[jnp.bfloat16 if dtype == jnp.bfloat16 else jnp.float32]


# ---------------------------------------------------------------- flash attn
@pytest.mark.parametrize("b,s,h,kh,d", [
    (1, 128, 4, 4, 64),      # MHA
    (2, 256, 8, 2, 64),      # GQA 4:1
    (1, 256, 4, 1, 128),     # MQA
    (2, 128, 4, 4, 80),      # non-lane head dim (padding path)
    (1, 384, 6, 6, 64),      # seq not a block multiple
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_shapes(b, s, h, kh, d, dtype):
    key = jax.random.PRNGKey(0)
    kq, kk, kv = jax.random.split(key, 3)
    q = (jax.random.normal(kq, (b, s, h, d)) * 0.5).astype(dtype)
    k = (jax.random.normal(kk, (b, s, kh, d)) * 0.5).astype(dtype)
    v = (jax.random.normal(kv, (b, s, kh, d)) * 0.5).astype(dtype)
    got = ops.flash_attention(q, k, v, block_q=128, block_k=128,
                              interpret=True)
    want = ref.flash_attention_ref(
        q.reshape(b, s, kh, h // kh, d).transpose(0, 2, 3, 1, 4)
         .reshape(b * h, s, d) if False else
        jnp.moveaxis(q, 2, 1).reshape(b * h, s, d),
        jnp.moveaxis(k, 2, 1).reshape(b * kh, s, d),
        jnp.moveaxis(v, 2, 1).reshape(b * kh, s, d))
    want = jnp.moveaxis(want.reshape(b, h, s, d), 1, 2)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), **_tols(dtype))


@pytest.mark.parametrize("window", [32, 128])
def test_flash_attention_sliding_window(window):
    key = jax.random.PRNGKey(1)
    b, s, h, d = 1, 256, 2, 64
    q, k, v = (jax.random.normal(kk, (b, s, h, d)) * 0.5
               for kk in jax.random.split(key, 3))
    got = ops.flash_attention(q, k, v, window=window, block_q=64, block_k=64,
                              interpret=True)
    want = ref.flash_attention_ref(
        jnp.moveaxis(q, 2, 1).reshape(b * h, s, d),
        jnp.moveaxis(k, 2, 1).reshape(b * h, s, d),
        jnp.moveaxis(v, 2, 1).reshape(b * h, s, d), window=window)
    want = jnp.moveaxis(want.reshape(b, h, s, d), 1, 2)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-3,
                               atol=2e-3)


def test_flash_attention_matches_model_layer():
    """Kernel ≡ the model substrate's attention_full (the integration oracle)."""
    from repro.models.layers import attention_full
    key = jax.random.PRNGKey(2)
    b, s, h, kh, d = 2, 256, 8, 2, 64
    q = jax.random.normal(key, (b, s, h, d)) * 0.5
    k = jax.random.normal(key, (b, s, kh, d)) * 0.5
    v = jax.random.normal(key, (b, s, kh, d)) * 0.5
    pos = jnp.arange(s, dtype=jnp.int32)
    want = attention_full(q, k, v, pos, pos, 0, d ** -0.5)
    got = ops.flash_attention(q, k, v, interpret=True)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-3,
                               atol=2e-3)


# ------------------------------------------------------------- decode attn
@pytest.mark.parametrize("b,h,kh,d,t", [
    (2, 8, 2, 64, 1024),
    (1, 4, 4, 128, 512),
    (4, 4, 1, 80, 768),     # MQA + padded head dim
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_decode_attention_shapes(b, h, kh, d, t, dtype):
    key = jax.random.PRNGKey(3)
    kq, kk, kv = jax.random.split(key, 3)
    q = (jax.random.normal(kq, (b, 1, h, d)) * 0.5).astype(dtype)
    k = (jax.random.normal(kk, (b, t, kh, d)) * 0.5).astype(dtype)
    v = (jax.random.normal(kv, (b, t, kh, d)) * 0.5).astype(dtype)
    # ring cache with some empty slots
    pos = jnp.where(jnp.arange(t) < t - 100, jnp.arange(t), -1).astype(jnp.int32)
    got = ops.decode_attention(q, k, v, pos, block_k=256, interpret=True)
    g = h // kh
    qq = q.reshape(b, kh, g, d).reshape(b * kh, g, d)
    kk2 = jnp.moveaxis(k, 2, 1).reshape(b * kh, t, d)
    vv2 = jnp.moveaxis(v, 2, 1).reshape(b * kh, t, d)
    want = ref.decode_attention_ref(qq, kk2, vv2, pos)
    want = want.reshape(b, kh, g, d).reshape(b, 1, h, d)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), **_tols(dtype))


def test_decode_attention_matches_model_decode():
    """Kernel ≡ the substrate's masked attention_core decode path."""
    from repro.models.layers import attention_core
    key = jax.random.PRNGKey(4)
    b, h, kh, d, t = 2, 4, 2, 64, 512
    q = jax.random.normal(key, (b, 1, h, d)) * 0.5
    k = jax.random.normal(key, (b, t, kh, d)) * 0.5
    v = jax.random.normal(key, (b, t, kh, d)) * 0.5
    pos = jnp.where(jnp.arange(t) < 300, jnp.arange(t), -1).astype(jnp.int32)
    want = attention_core(q, k, v, (pos >= 0)[None, :], d ** -0.5)
    got = ops.decode_attention(q, k, v, pos, block_k=128, interpret=True)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-3,
                               atol=2e-3)


# ---------------------------------------------------------------- ssd scan
@pytest.mark.parametrize("b,slen,h,p,g,n,chunk", [
    (2, 64, 4, 16, 1, 16, 16),
    (1, 128, 2, 64, 1, 128, 32),     # mamba2-130m-like dims
    (2, 96, 4, 32, 2, 32, 32),       # grouped B/C
])
def test_ssd_scan_shapes(b, slen, h, p, g, n, chunk):
    key = jax.random.PRNGKey(5)
    ks = jax.random.split(key, 4)
    x = jax.random.normal(ks[0], (b, slen, h, p)) * 0.5
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b, slen, h)))
    a_log = jnp.log(jnp.linspace(0.5, 4.0, h))
    bb = jax.random.normal(ks[2], (b, slen, g, n)) * 0.5
    cc = jax.random.normal(ks[3], (b, slen, g, n)) * 0.5
    y, state = ops.ssd_scan(x, dt, a_log, bb, cc, chunk=chunk,
                            interpret=True)

    # oracle via the same pre-scaling the wrapper does
    a = -jnp.exp(a_log)
    da = dt * a
    xdt = x * dt[..., None]
    rep = h // g
    nc = slen // chunk
    def arr(z):
        z = jnp.moveaxis(z, 2, 1)
        return z.reshape(z.shape[0], z.shape[1], nc, chunk, *z.shape[3:])
    y_ref, s_ref = ref.ssd_scan_ref(
        arr(xdt), jnp.moveaxis(da, 2, 1).reshape(b, h, nc, chunk),
        arr(jnp.repeat(bb, rep, axis=2)), arr(jnp.repeat(cc, rep, axis=2)))
    y_ref = jnp.moveaxis(y_ref.reshape(b, h, slen, p), 1, 2)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref), rtol=2e-3,
                               atol=2e-3)
    np.testing.assert_allclose(np.asarray(state),
                               np.asarray(jnp.swapaxes(s_ref, -1, -2)),
                               rtol=2e-3, atol=2e-3)


def test_ssd_scan_matches_model_ssd():
    """Kernel ≡ models.ssm.ssd_chunked (the substrate integration oracle)."""
    from repro.models.ssm import ssd_chunked
    key = jax.random.PRNGKey(6)
    b, slen, h, p, g, n = 2, 64, 4, 16, 1, 16
    ks = jax.random.split(key, 4)
    x = jax.random.normal(ks[0], (b, slen, h, p)) * 0.5
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b, slen, h)))
    a_log = jnp.log(jnp.linspace(0.5, 4.0, h))
    bb = jax.random.normal(ks[2], (b, slen, g, n)) * 0.5
    cc = jax.random.normal(ks[3], (b, slen, g, n)) * 0.5
    y_want, s_want = ssd_chunked(x, dt, a_log, bb, cc, 16)
    y_got, s_got = ops.ssd_scan(x, dt, a_log, bb, cc, chunk=16,
                                interpret=True)
    np.testing.assert_allclose(np.asarray(y_got), np.asarray(y_want),
                               rtol=2e-3, atol=2e-3)
    np.testing.assert_allclose(np.asarray(s_got), np.asarray(s_want),
                               rtol=2e-3, atol=2e-3)


# ------------------------------------------------------------ embedding bag
@pytest.mark.parametrize("n_bags,bag,v,d", [
    (4, 8, 64, 32), (8, 4, 128, 64), (2, 16, 32, 80),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_embedding_bag_shapes(n_bags, bag, v, d, dtype):
    key = jax.random.PRNGKey(7)
    table = (jax.random.normal(key, (v, d)) * 0.5).astype(dtype)
    idx = jax.random.randint(key, (n_bags, bag), 0, v).astype(jnp.int32)
    got = ops.embedding_bag(idx, table, interpret=True)
    want = ref.embedding_bag_ref(idx, table)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), **_tols(dtype))


def test_embedding_bag_weighted():
    key = jax.random.PRNGKey(8)
    table = jax.random.normal(key, (64, 32))
    idx = jax.random.randint(key, (4, 8), 0, 64).astype(jnp.int32)
    w = jax.random.uniform(key, (4, 8))
    got = ops.embedding_bag(idx, table, w, interpret=True)
    want = ref.embedding_bag_ref(idx, table, w)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-4,
                               atol=2e-4)


def test_embedding_bag_duplicate_indices():
    """Multi-hot bags repeat rows; the sum must count multiplicity."""
    table = jnp.eye(8, 16)
    idx = jnp.array([[3, 3, 3, 1]], dtype=jnp.int32)
    got = ops.embedding_bag(idx, table, interpret=True)
    want = 3 * table[3] + table[1]
    np.testing.assert_allclose(np.asarray(got[0]), np.asarray(want))
