#!/usr/bin/env python
"""Render perf-trend charts from ``bench_out/history.jsonl``.

``scripts/check_bench.py --history`` upserts one row per validated artifact
keyed by (commit, bench, source); this script turns that log into a small
grid of per-metric trend lines (one subplot per (bench, source) pair,
commits on the x-axis in log order) and writes a single PNG artifact for
CI upload.

matplotlib is an optional dependency: when it is not installed the script
prints a note and exits 0, so the CI step degrades gracefully on minimal
runners instead of failing the build over a plotting library.

Usage::

    python scripts/plot_history.py                      # default paths
    python scripts/plot_history.py --history-file PATH --out PATH
"""

from __future__ import annotations

import argparse
import json
from pathlib import Path

REPO_ROOT = Path(__file__).resolve().parent.parent


def load_history(path: Path) -> list[dict]:
    entries = []
    for line in path.read_text().splitlines():
        try:
            entry = json.loads(line)
        except json.JSONDecodeError:
            continue
        if isinstance(entry, dict) and isinstance(entry.get("metrics"), dict):
            entries.append(entry)
    return entries


def group_series(entries: list[dict]) -> dict:
    """(bench, source) -> {metric -> [(commit, value, direction), ...]} in
    log order (the log is append-ordered; check_bench upserts per commit)."""
    groups: dict[tuple[str, str], dict[str, list]] = {}
    for entry in entries:
        key = (str(entry.get("bench")), str(entry.get("source")))
        series = groups.setdefault(key, {})
        for name, value in entry["metrics"].items():
            if isinstance(value, list) and len(value) == 2:
                val, direction = value
            else:
                val, direction = value, "higher"
            if not isinstance(val, (int, float)):
                continue
            series.setdefault(name, []).append(
                (str(entry.get("commit", "?")), float(val), str(direction)))
    return groups


def render(groups: dict, out: Path) -> Path:
    import matplotlib
    matplotlib.use("Agg")
    import matplotlib.pyplot as plt

    n = max(1, len(groups))
    fig, axes = plt.subplots(n, 1, figsize=(10, 3.2 * n), squeeze=False)
    for ax, ((bench, source), series) in zip(axes.ravel(), sorted(groups.items())):
        for name, points in sorted(series.items()):
            commits = [c for c, _, _ in points]
            values = [v for _, v, _ in points]
            direction = points[-1][2]
            marker = "^" if direction == "higher" else "v"
            ax.plot(range(len(values)), values, marker=marker,
                    label=f"{name} ({direction} is better)")
            ax.set_xticks(range(len(commits)))
            ax.set_xticklabels(commits, rotation=45, ha="right", fontsize=7)
        ax.set_title(f"{bench} — {source}", fontsize=9)
        ax.legend(fontsize=7)
        ax.grid(True, alpha=0.3)
    fig.tight_layout()
    out.parent.mkdir(exist_ok=True)
    fig.savefig(out, dpi=120)
    plt.close(fig)
    return out


def main(argv=None) -> int:
    parser = argparse.ArgumentParser(description=__doc__)
    parser.add_argument(
        "--history-file",
        type=Path,
        default=REPO_ROOT / "bench_out" / "history.jsonl",
        help="history log written by check_bench.py --history",
    )
    parser.add_argument(
        "--out",
        type=Path,
        default=REPO_ROOT / "bench_out" / "history_trends.png",
        help="output PNG path",
    )
    args = parser.parse_args(argv)

    if not args.history_file.exists():
        print(f"plot_history: no history at {args.history_file} — "
              "run scripts/check_bench.py --history first; nothing to plot")
        return 0
    entries = load_history(args.history_file)
    if not entries:
        print(f"plot_history: {args.history_file} holds no metric rows; "
              "nothing to plot")
        return 0
    try:
        import matplotlib  # noqa: F401
    except ImportError:
        print("plot_history: matplotlib not installed — skipping chart "
              "(history log is unaffected)")
        return 0
    out = render(group_series(entries), args.out)
    print(f"plot_history: wrote {out} "
          f"({len(entries)} history rows)")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
