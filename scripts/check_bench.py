#!/usr/bin/env python
"""CI gate on benchmark artifacts.

Two responsibilities:

* **Schema validation** of every ``BENCH_*.json`` artifact (the committed
  repo-root baseline plus everything under ``bench_out/``): the stable
  envelope (``schema_version``, ``bench``) must be present and every number
  in the document must be finite — NaN/Infinity silently round-trip through
  ``json`` and would otherwise slip past threshold comparisons.
* **Perf thresholds** on the batched evaluation engine
  (``bench == "batch_eval"``): batched B=32 must stay >= 5x the sequential
  single-config path, and the joint (workload x config) grid dispatch at
  W=4 x B=32 must stay >= 3x the per-workload sequential sweep and remain
  bit-identical to it.  Smoke artifacts (``--smoke``/``--quick`` runs on a
  shrunken workload, ``n_queries < 1500``) gate B=32 at a reduced floor —
  fixed per-dispatch overhead is a larger fraction of the shorter sweeps
  and CI runners are noisy, but a real regression (the pre-batched
  sequential path measures ~1x) still lands far below it.  The grid
  measurement is always taken at full workload size, so its threshold is
  uniform.

Usage::

    python scripts/check_bench.py                 # root baseline + bench_out
    python scripts/check_bench.py PATH [PATH...]  # explicit artifacts
    python scripts/check_bench.py --schema-only   # skip perf thresholds

``--schema-only`` lets CI validate artifacts produced on arbitrary hardware
without asserting hardware-dependent speedups.
"""

from __future__ import annotations

import argparse
import json
import math
from pathlib import Path

REPO_ROOT = Path(__file__).resolve().parent.parent
SCHEMA_VERSION = 1
FULL_N_QUERIES = 1500
MIN_SPEEDUP_AT_32 = 5.0
MIN_GRID_SPEEDUP = 3.0
# Smoke (--quick/--smoke) artifacts measure B=32 on a shrunken workload;
# gate it at a reduced floor.  The grid section is always measured at full
# workload size (see benchmarks/bench_batch_eval.GRID_N_QUERIES), so its
# threshold does not scale down.
SMOKE_MIN_SPEEDUP_AT_32 = 4.0

RESULT_KEYS = (
    "batch_size",
    "wall_time_single_s",
    "wall_time_batched_s",
    "speedup",
)
GRID_KEYS = (
    "n_workloads",
    "batch_size",
    "wall_time_sequential_s",
    "wall_time_grid_s",
    "speedup",
    "bit_identical",
)


def iter_numbers(obj, path="$"):
    """Yield (json_path, value) for every number in a decoded document."""
    if isinstance(obj, bool):
        return
    if isinstance(obj, (int, float)):
        yield path, float(obj)
    elif isinstance(obj, dict):
        for key, value in obj.items():
            yield from iter_numbers(value, f"{path}.{key}")
    elif isinstance(obj, list):
        for i, value in enumerate(obj):
            yield from iter_numbers(value, f"{path}[{i}]")


def validate_schema(doc, label: str) -> list[str]:
    errors = []
    if not isinstance(doc, dict):
        return [f"{label}: top level must be an object"]
    if doc.get("schema_version") != SCHEMA_VERSION:
        errors.append(
            f"{label}: schema_version={doc.get('schema_version')!r}"
            f" (expected {SCHEMA_VERSION})",
        )
    bench = doc.get("bench")
    if not isinstance(bench, str) or not bench:
        errors.append(f"{label}: missing or empty 'bench' name")
    for path, value in iter_numbers(doc):
        if not math.isfinite(value):
            errors.append(f"{label}: non-finite number at {path}")
    return errors


def check_batch_eval(doc, label: str) -> list[str]:
    """Perf thresholds for the batched/grid evaluation engine baseline."""
    errors = []
    # A missing n_queries field gates at the strict full-size thresholds —
    # only an explicit shrunken workload earns the smoke floor.
    n_queries = doc.get("n_queries")
    smoke = n_queries is not None and float(n_queries) < FULL_N_QUERIES
    min_b32 = SMOKE_MIN_SPEEDUP_AT_32 if smoke else MIN_SPEEDUP_AT_32
    min_grid = MIN_GRID_SPEEDUP
    results = doc.get("results")
    if not isinstance(results, list) or not results:
        return [f"{label}: batch_eval artifact has no 'results'"]
    by_b = {}
    for i, row in enumerate(results):
        missing = [k for k in RESULT_KEYS if k not in row]
        if missing:
            errors.append(f"{label}: results[{i}] missing keys {missing}")
            continue
        by_b[row["batch_size"]] = row
    if 32 not in by_b:
        errors.append(f"{label}: no B=32 measurement in results")
    else:
        speedup = float(by_b[32]["speedup"])
        if speedup < min_b32:
            errors.append(
                f"{label}: batched B=32 speedup {speedup:.2f}x"
                f" < required {min_b32:.1f}x",
            )
    grid = doc.get("grid")
    if not isinstance(grid, dict):
        errors.append(f"{label}: batch_eval artifact has no 'grid' section")
        return errors
    missing = [k for k in GRID_KEYS if k not in grid]
    if missing:
        errors.append(f"{label}: grid section missing keys {missing}")
        return errors
    if not grid["bit_identical"]:
        errors.append(f"{label}: grid results diverge from sequential sweep")
    speedup = float(grid["speedup"])
    if speedup < min_grid:
        errors.append(
            f"{label}: grid W={grid['n_workloads']} B={grid['batch_size']}"
            f" speedup {speedup:.2f}x < required {min_grid:.1f}x",
        )
    return errors


def default_paths(bench_dir: Path) -> list[Path]:
    paths = []
    root_baseline = REPO_ROOT / "BENCH_batch_eval.json"
    if root_baseline.exists():
        paths.append(root_baseline)
    if bench_dir.is_dir():
        paths.extend(sorted(bench_dir.glob("BENCH_*.json")))
    return paths


def main(argv=None) -> int:
    parser = argparse.ArgumentParser(description=__doc__)
    parser.add_argument(
        "paths",
        nargs="*",
        type=Path,
        help="artifacts to check (default: repo-root baseline + bench_out)",
    )
    parser.add_argument(
        "--schema-only",
        action="store_true",
        help="validate schemas only; skip hardware-dependent thresholds",
    )
    parser.add_argument(
        "--bench-dir",
        type=Path,
        default=REPO_ROOT / "bench_out",
        help="directory scanned for BENCH_*.json in default mode",
    )
    args = parser.parse_args(argv)

    paths = list(args.paths) or default_paths(args.bench_dir)
    if not paths:
        print(
            "check_bench: no artifacts found — run "
            "`PYTHONPATH=src python -m benchmarks.bench_batch_eval` first",
        )
        return 1

    errors = []
    for path in paths:
        label = str(path)
        if not path.exists():
            errors.append(f"{label}: not found")
            continue
        try:
            doc = json.loads(path.read_text())
        except json.JSONDecodeError as exc:
            errors.append(f"{label}: invalid JSON ({exc})")
            continue
        schema_errors = validate_schema(doc, label)
        errors.extend(schema_errors)
        if args.schema_only or schema_errors:
            continue
        if doc.get("bench") == "batch_eval":
            errors.extend(check_batch_eval(doc, label))

    if errors:
        for err in errors:
            print(f"check_bench: FAIL — {err}")
        return 1
    mode = "schemas" if args.schema_only else "schemas + perf gates"
    print(f"check_bench: OK — {len(paths)} artifact(s), {mode}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
