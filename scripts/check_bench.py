#!/usr/bin/env python
"""CI gate on benchmark artifacts.

Three responsibilities:

* **Schema validation** of every ``BENCH_*.json`` artifact (the committed
  repo-root baseline plus everything under ``bench_out/``): the stable
  envelope (``schema_version``, ``bench``) must be present and every number
  in the document must be finite — NaN/Infinity silently round-trip through
  ``json`` and would otherwise slip past threshold comparisons.
* **Perf/behavior thresholds** per bench kind:
  - ``bench == "batch_eval"``: batched B=32 must stay >= 5x the sequential
    single-config path; the joint (workload x config) grid dispatch at
    W=4 x B=32 must stay >= 3x the per-workload sequential sweep and remain
    bit-identical to it; the warm candidate lanes (B=32 what-if pools
    scored from a live backlog in one dispatch) must stay >= 3x the
    sequential per-candidate warm path, bit-identical to it, with a nonzero
    mean warm-vs-idle scoring delta (the carried backlog must actually move
    the scores); and the routing section must show the joint
    (policy x config) stacked dispatch >= 3x its sequential single-config
    baseline, bit-identical per policy row, with the flash-crowd economics
    holding — the cheapest routed-feasible pool at the surge load strictly
    cheaper than the cheapest FCFS-feasible pool at the same QoS target.
    The telemetry section must show the telemetry-on batch lane within
    10% of the telemetry-off wall time (the twin scan kernels pay for the
    extra outputs with a one-hot carry update and occupancy-trimmed slot
    axis), primary outputs bit-identical with telemetry off, and per-type
    served counts summing exactly to ``n_queries`` on every lane.
    Smoke artifacts (``--smoke``/``--quick`` runs on a shrunken workload,
    ``n_queries < 1500``) gate B=32, the warm lane and the routing lane at
    reduced floors, and the telemetry overhead at a looser ceiling — fixed per-dispatch overhead is a larger fraction of
    the shorter sweeps and CI runners are noisy, but a real regression (the
    pre-batched sequential path measures ~1x) still lands far below them.
    The grid measurement is always taken at full workload size, so its
    threshold is uniform — except on single-device hosts (the artifact
    records ``grid.n_devices``), where the XLA lane sharding the ratio
    mostly comes from is unavailable and the floor drops to 1.3x.
  - ``bench == "scenarios"``: every episode must report
    ``recovered_all_events`` — each injected event's QoS returned to target
    within the episode (finite adaptation latency); episodes with an
    ``idle_baselines`` entry must report at least as many violation windows
    as the idle-restart baseline (the continuous episode clock carries
    queue backlog across control-plane cuts; losing that mass again would
    be a regression to the optimistic accounting) — compared against the
    ``matched_scoring`` replay when the artifact records one, because only
    matched (idle) candidate scoring pins both runs to the same control
    trajectory; and the flash-crowd / failure-storm episodes must report a
    nonzero ``warm_idle_delta_total`` (their warm-scored adaptations run
    from real backlog, so idle scoring was measurably optimistic).  When the
    artifact carries a ``tiers`` section (the hybrid capacity-tier runs),
    the tier gates in ``check_tiers`` apply on top: hybrid strictly cheaper
    than every QoS-matching single-tier baseline, storms/outages recovered,
    carried violation mass >= the idle baseline's, and the tiered composite
    fuzz recovered on every seed.
* **Perf-trend history** (``--history``): upsert every validated artifact's
  trend metrics into ``bench_out/history.jsonl`` keyed by
  (commit, bench, source) — re-running on the same commit replaces the row,
  so trends always compare distinct commits — and WARN (non-fatal — CI
  runners are noisy and hardware varies) when a metric regressed by more
  than 20% against the most recent entry from a different commit.

Usage::

    python scripts/check_bench.py                 # root baseline + bench_out
    python scripts/check_bench.py PATH [PATH...]  # explicit artifacts
    python scripts/check_bench.py --schema-only   # skip perf thresholds
    python scripts/check_bench.py --history       # also append + trend-check

``--schema-only`` lets CI validate artifacts produced on arbitrary hardware
without asserting hardware-dependent speedups.  It short-circuits
``--history`` as well: schema-only validation performs no history I/O and
prints no trend warnings (a schema sweep must not mutate the trend log or
spam WARN lines about thresholds it was told to skip).
"""

from __future__ import annotations

import argparse
import json
import math
import subprocess
from pathlib import Path

REPO_ROOT = Path(__file__).resolve().parent.parent
SCHEMA_VERSION = 1
FULL_N_QUERIES = 1500
MIN_SPEEDUP_AT_32 = 5.0
MIN_GRID_SPEEDUP = 3.0
# Smoke (--quick/--smoke) artifacts measure B=32 on a shrunken workload;
# gate it at a reduced floor.  The grid section is always measured at full
# workload size (see benchmarks/bench_batch_eval.GRID_N_QUERIES), so its
# threshold does not scale down.
SMOKE_MIN_SPEEDUP_AT_32 = 4.0
# The grid ratio mostly comes from sharding the flattened lane axis across
# XLA host devices; a single-device host (grid.n_devices == 1) can only
# amortize dispatch overhead, so it gates at a reduced floor (a regression
# to the pre-grid sequential path still measures ~1x).
SINGLE_DEVICE_MIN_GRID_SPEEDUP = 1.3
# Warm candidate lanes (one dispatch scoring B what-if pools from a live
# backlog) vs B sequential warm single-config calls.  The sequential
# baseline pays per-candidate host-side prefix bookkeeping, so the floor is
# below the cold B=32 gate; smoke runs gate lower still.
MIN_WARM_SPEEDUP = 3.0
SMOKE_MIN_WARM_SPEEDUP = 2.5
# Routing: one stacked-policy dispatch scoring P policies x B pools vs the
# P x B sequential single-config policy evaluations.
MIN_ROUTING_SPEEDUP = 3.0
SMOKE_MIN_ROUTING_SPEEDUP = 2.5
# Telemetry plane: qos(telemetry=True) vs the plain call on the B=32 batch
# lane.  The smoke ceiling is looser because both sides of the shrunken
# ratio are a few milliseconds and timer noise alone swings the quotient
# past the 10% margin.
MAX_TELEMETRY_OVERHEAD = 1.10
SMOKE_MAX_TELEMETRY_OVERHEAD = 1.25
# Episodes whose warm run must show a nonzero warm-vs-idle scoring delta
# (mirrors benchmarks/bench_scenarios.WARM_DELTA_EPISODES).
WARM_DELTA_EPISODES = ("flash-crowd", "failure-storm")
# Streamed-episode artifacts (bench == "stream").  A full run streams >= 1M
# queries; anything smaller is a smoke artifact and gates at reduced
# floors.  The throughput floors sit ~20x below a healthy CPU measurement
# (~2M queries/s), so they trip on a real regression (e.g. the stream
# falling back to per-query host dispatch), not on runner noise.  The
# memory ratio gates the constant-memory claim itself: peak live device
# bytes at 4n vs n queries must stay flat (chunk-sized buffers only).
FULL_STREAM_N = 1_000_000
MIN_STREAM_QPS = 100_000.0
SMOKE_MIN_STREAM_QPS = 10_000.0
MAX_STREAM_MEM_RATIO = 1.10
SMOKE_MAX_STREAM_MEM_RATIO = 1.25
# Mélange exact-baseline artifacts (bench == "cost_savings").  ``bo_gap``
# is (bo_cost - exact_cost) / exact_cost against the throughput-relaxation
# optimum from core.baselines.solve_bucketed — a lower bound that ignores
# queueing headroom, so BO legitimately pays a premium above it (observed
# up to ~1.35x on mtwnd at the 0.99 target).  The ceiling trips when BO
# stops converging (it previously landed ~3x over on regressions of the
# start heuristic); the floor trips when BO lands impossibly far *below*
# the relaxation, which means the throughput table or solver broke.
MAX_BO_GAP = 2.0
SMOKE_MAX_BO_GAP = 3.0
MIN_BO_GAP = -0.5
MELANGE_MODEL_KEYS = ("exact_config", "exact_cost", "solver_method",
                      "bo_cost", "bo_gap", "bo_feasible")

RESULT_KEYS = (
    "batch_size",
    "wall_time_single_s",
    "wall_time_batched_s",
    "speedup",
)
GRID_KEYS = (
    "n_workloads",
    "batch_size",
    "wall_time_sequential_s",
    "wall_time_grid_s",
    "speedup",
    "bit_identical",
)
WARM_KEYS = (
    "batch_size",
    "wall_time_sequential_s",
    "wall_time_batched_s",
    "speedup",
    "bit_identical",
    "warm_idle_delta_mean",
)
ROUTING_KEYS = (
    "batch_size",
    "n_policies",
    "wall_time_sequential_s",
    "wall_time_joint_s",
    "speedup",
    "bit_identical",
    "surge_factor",
    "qos_target",
    "fcfs_min_cost",
    "routed_min_cost",
)
TELEMETRY_KEYS = (
    "batch_size",
    "wall_time_off_s",
    "wall_time_on_s",
    "overhead",
    "bit_identical",
    "served_counts_ok",
)
STREAM_KEYS = (
    "n_queries",
    "chunk",
    "elapsed_s",
    "qps",
    "qos_rate",
    "rebases",
)
STREAM_MEMORY_KEYS = (
    "n_small",
    "n_large",
    "peak_small_bytes",
    "peak_large_bytes",
    "ratio",
)
STREAM_DAY_KEYS = (
    "episode",
    "total_queries",
    "qos_rate",
    "total_cost",
    "completed",
)


def iter_numbers(obj, path="$"):
    """Yield (json_path, value) for every number in a decoded document."""
    if isinstance(obj, bool):
        return
    if isinstance(obj, (int, float)):
        yield path, float(obj)
    elif isinstance(obj, dict):
        for key, value in obj.items():
            yield from iter_numbers(value, f"{path}.{key}")
    elif isinstance(obj, list):
        for i, value in enumerate(obj):
            yield from iter_numbers(value, f"{path}[{i}]")


def validate_schema(doc, label: str) -> list[str]:
    errors = []
    if not isinstance(doc, dict):
        return [f"{label}: top level must be an object"]
    if doc.get("schema_version") != SCHEMA_VERSION:
        errors.append(
            f"{label}: schema_version={doc.get('schema_version')!r}"
            f" (expected {SCHEMA_VERSION})",
        )
    bench = doc.get("bench")
    if not isinstance(bench, str) or not bench:
        errors.append(f"{label}: missing or empty 'bench' name")
    for path, value in iter_numbers(doc):
        if not math.isfinite(value):
            errors.append(f"{label}: non-finite number at {path}")
    return errors


def check_batch_eval(doc, label: str) -> list[str]:
    """Perf thresholds for the batched/grid evaluation engine baseline."""
    errors = []
    # A missing n_queries field gates at the strict full-size thresholds —
    # only an explicit shrunken workload earns the smoke floor.
    n_queries = doc.get("n_queries")
    smoke = n_queries is not None and float(n_queries) < FULL_N_QUERIES
    min_b32 = SMOKE_MIN_SPEEDUP_AT_32 if smoke else MIN_SPEEDUP_AT_32
    min_grid = MIN_GRID_SPEEDUP
    results = doc.get("results")
    if not isinstance(results, list) or not results:
        return [f"{label}: batch_eval artifact has no 'results'"]
    by_b = {}
    for i, row in enumerate(results):
        missing = [k for k in RESULT_KEYS if k not in row]
        if missing:
            errors.append(f"{label}: results[{i}] missing keys {missing}")
            continue
        by_b[row["batch_size"]] = row
    if 32 not in by_b:
        errors.append(f"{label}: no B=32 measurement in results")
    else:
        speedup = float(by_b[32]["speedup"])
        if speedup < min_b32:
            errors.append(
                f"{label}: batched B=32 speedup {speedup:.2f}x"
                f" < required {min_b32:.1f}x",
            )
    grid = doc.get("grid")
    if not isinstance(grid, dict):
        errors.append(f"{label}: batch_eval artifact has no 'grid' section")
        return errors
    missing = [k for k in GRID_KEYS if k not in grid]
    if missing:
        errors.append(f"{label}: grid section missing keys {missing}")
        return errors
    if not grid["bit_identical"]:
        errors.append(f"{label}: grid results diverge from sequential sweep")
    # Artifacts predating the n_devices field were all measured on
    # multi-device hosts; they keep the full threshold.
    if int(grid.get("n_devices", 2)) <= 1:
        min_grid = SINGLE_DEVICE_MIN_GRID_SPEEDUP
    speedup = float(grid["speedup"])
    if speedup < min_grid:
        errors.append(
            f"{label}: grid W={grid['n_workloads']} B={grid['batch_size']}"
            f" speedup {speedup:.2f}x < required {min_grid:.1f}x",
        )
    min_warm = SMOKE_MIN_WARM_SPEEDUP if smoke else MIN_WARM_SPEEDUP
    warm = doc.get("warm")
    if not isinstance(warm, dict):
        errors.append(f"{label}: batch_eval artifact has no 'warm' section")
        return errors
    missing = [k for k in WARM_KEYS if k not in warm]
    if missing:
        errors.append(f"{label}: warm section missing keys {missing}")
        return errors
    if not warm["bit_identical"]:
        errors.append(
            f"{label}: warm batch results diverge from the sequential "
            "warm single-config path",
        )
    if not float(warm["warm_idle_delta_mean"]) > 0.0:
        errors.append(
            f"{label}: warm-vs-idle scoring delta is zero — the carried "
            "backlog no longer moves candidate scores",
        )
    speedup = float(warm["speedup"])
    if speedup < min_warm:
        errors.append(
            f"{label}: warm B={warm['batch_size']} speedup {speedup:.2f}x"
            f" < required {min_warm:.1f}x",
        )
    min_route = SMOKE_MIN_ROUTING_SPEEDUP if smoke else MIN_ROUTING_SPEEDUP
    routing = doc.get("routing")
    if not isinstance(routing, dict):
        errors.append(f"{label}: batch_eval artifact has no 'routing' "
                      "section")
        return errors
    missing = [k for k in ROUTING_KEYS if k not in routing]
    if missing:
        errors.append(f"{label}: routing section missing keys {missing}")
        return errors
    if not routing["bit_identical"]:
        errors.append(
            f"{label}: joint (policy x config) rates diverge from the "
            "sequential per-policy dispatches",
        )
    speedup = float(routing["speedup"])
    if speedup < min_route:
        errors.append(
            f"{label}: routing P={routing['n_policies']} "
            f"B={routing['batch_size']} joint speedup {speedup:.2f}x"
            f" < required {min_route:.1f}x",
        )
    fcfs_cost = float(routing["fcfs_min_cost"])
    routed_cost = float(routing["routed_min_cost"])
    if not routed_cost < fcfs_cost:
        errors.append(
            f"{label}: routed pool does not beat FCFS on cost at the "
            f"flash-crowd surge (routed {routed_cost:.4g} vs FCFS "
            f"{fcfs_cost:.4g} at QoS >= {routing['qos_target']}, "
            f"load x{routing['surge_factor']})",
        )
    max_tel = (SMOKE_MAX_TELEMETRY_OVERHEAD if smoke
               else MAX_TELEMETRY_OVERHEAD)
    tel = doc.get("telemetry")
    if not isinstance(tel, dict):
        errors.append(f"{label}: batch_eval artifact has no 'telemetry' "
                      "section")
        return errors
    missing = [k for k in TELEMETRY_KEYS if k not in tel]
    if missing:
        errors.append(f"{label}: telemetry section missing keys {missing}")
        return errors
    if not tel["bit_identical"]:
        errors.append(
            f"{label}: primary outputs with telemetry off diverge from the "
            "telemetry-on twin kernels",
        )
    if not tel["served_counts_ok"]:
        bad = [lane for lane, ok
               in (tel.get("served_counts_by_lane") or {}).items() if not ok]
        errors.append(
            f"{label}: per-type served counts do not sum to n_queries on "
            f"lane(s) {bad or '?'}",
        )
    overhead = float(tel["overhead"])
    if overhead > max_tel:
        errors.append(
            f"{label}: telemetry-on overhead {overhead:.3f}x on the B="
            f"{tel['batch_size']} batch lane > allowed {max_tel:.2f}x",
        )
    return errors


def check_scenarios(doc, label: str) -> list[str]:
    """Behavior gates for scenario-engine episode artifacts: every injected
    event must have recovered (finite adaptation latency), and episodes with
    a recorded idle-restart baseline must report at least as much
    violation-window mass as that baseline — the continuous-time episode
    clock carries queue backlog across control-plane cuts, which idle
    restarts used to hide.  The comparison runs against the artifact's
    ``matched_scoring`` replay when present (carried clock + idle candidate
    scoring): matched scoring pins both runs to the same control
    trajectory, where the invariant genuinely holds — the headline warm
    runs score candidates from the backlog and may legitimately adapt
    *better* than the idle baseline.  Those warm runs are instead gated on
    a nonzero warm-vs-idle scoring delta for the episodes that inject real
    backlog at adaptation cuts (``WARM_DELTA_EPISODES``).  All replays are
    deterministic per seed, so these are fidelity tripwires rather than
    theorems: a control-policy change that legitimately moves a gated
    number should be inspected and re-baselined in bench_scenarios, not
    silenced."""
    errors = []
    episodes = doc.get("episodes")
    if not isinstance(episodes, dict) or not episodes:
        return [f"{label}: scenarios artifact has no 'episodes'"]
    for name, ep in episodes.items():
        if not isinstance(ep, dict):
            errors.append(f"{label}: episode {name!r} is not an object")
            continue
        if not ep.get("recovered_all_events", False):
            events = ep.get("events", [])
            bad = [e.get("kind") for e in events if e.get("recovery_queries") is None]
            errors.append(
                f"{label}: episode {name!r} did not recover QoS to target "
                f"after event(s) {bad}",
            )
    matched = doc.get("matched_scoring")
    matched = matched if isinstance(matched, dict) else {}
    baselines = doc.get("idle_baselines")
    if isinstance(baselines, dict):
        for name, base in baselines.items():
            ep = matched.get(name) or episodes.get(name)
            if not isinstance(ep, dict) or not isinstance(base, dict):
                continue
            warm = ep.get("violation_windows")
            cold = base.get("violation_windows")
            if isinstance(warm, (int, float)) and isinstance(cold, (int, float)):
                if warm < cold:
                    errors.append(
                        f"{label}: episode {name!r} reports {warm} violation "
                        f"windows under the carried-state clock, fewer than "
                        f"its idle-restart baseline ({cold}) — backlog "
                        f"accounting went missing",
                    )
    for name in WARM_DELTA_EPISODES:
        ep = episodes.get(name)
        if not isinstance(ep, dict):
            continue
        delta = ep.get("warm_idle_delta_total")
        if delta is None:
            errors.append(
                f"{label}: episode {name!r} has no warm_idle_delta_total — "
                "warm candidate scoring went missing from the bench",
            )
        elif not float(delta) > 0.0:
            errors.append(
                f"{label}: episode {name!r} reports a zero warm-vs-idle "
                "candidate-scoring delta — adaptations are being scored "
                "from an idle queue again",
            )
    errors.extend(check_tiers(doc, label))
    return errors


def check_stream(doc, label: str) -> list[str]:
    """Gates for streamed-episode artifacts (benchmarks/bench_stream):
    the streamed QoS rate must equal the monolithic reference bit for bit,
    peak device memory must not scale with episode length (the
    constant-memory claim), throughput must clear the floor, and the
    end-to-end day episode must have completed — covering >= 1M queries on
    a full run."""
    errors = []
    stream = doc.get("stream")
    if not isinstance(stream, dict):
        return [f"{label}: stream artifact has no 'stream' section"]
    missing = [k for k in STREAM_KEYS if k not in stream]
    if missing:
        return [f"{label}: stream section missing keys {missing}"]
    full = float(stream["n_queries"]) >= FULL_STREAM_N
    min_qps = MIN_STREAM_QPS if full else SMOKE_MIN_STREAM_QPS
    qps = float(stream["qps"])
    if qps < min_qps:
        errors.append(
            f"{label}: streamed throughput {qps:.0f} queries/s"
            f" < required {min_qps:.0f}",
        )
    memory = doc.get("memory")
    if not isinstance(memory, dict):
        errors.append(f"{label}: stream artifact has no 'memory' section")
    else:
        missing = [k for k in STREAM_MEMORY_KEYS if k not in memory]
        if missing:
            errors.append(f"{label}: memory section missing keys {missing}")
        else:
            max_ratio = (MAX_STREAM_MEM_RATIO if full
                         else SMOKE_MAX_STREAM_MEM_RATIO)
            ratio = float(memory["ratio"])
            if ratio > max_ratio:
                errors.append(
                    f"{label}: peak device memory grew x{ratio:.3f} from "
                    f"{memory['n_small']} to {memory['n_large']} queries "
                    f"(> allowed x{max_ratio:.2f}) — streaming is no "
                    "longer constant-memory",
                )
    bit = doc.get("bit_identical")
    if not isinstance(bit, dict):
        errors.append(f"{label}: stream artifact has no 'bit_identical' "
                      "section")
    elif not bit.get("ok", False):
        errors.append(
            f"{label}: streamed QoS rate "
            f"{bit.get('streamed_rate')} != monolithic "
            f"{bit.get('monolithic_rate')} at n={bit.get('n_queries')}",
        )
    day = doc.get("day")
    if not isinstance(day, dict):
        errors.append(f"{label}: stream artifact has no 'day' section")
        return errors
    missing = [k for k in STREAM_DAY_KEYS if k not in day]
    if missing:
        errors.append(f"{label}: day section missing keys {missing}")
        return errors
    if not day["completed"]:
        errors.append(f"{label}: day episode did not complete")
    if full and float(day["total_queries"]) < FULL_STREAM_N:
        errors.append(
            f"{label}: full-size day episode covered "
            f"{day['total_queries']} queries, fewer than the required "
            f"{FULL_STREAM_N}",
        )
    return errors


def check_cost_savings(doc, label: str) -> list[str]:
    """Gates for the Mélange exact-baseline artifacts
    (benchmarks/bench_cost_savings): every model's section must carry the
    full key set, the exact solver must have produced a positive-cost pool
    (it is exact — infeasibility raises at bench time, so a degenerate
    artifact means the inputs were wrong), BO must have found a feasible
    pool, and BO's cost gap above the throughput-relaxation optimum must
    stay inside [MIN_BO_GAP, MAX_BO_GAP] (smoke: SMOKE_MAX_BO_GAP)."""
    errors = []
    melange = doc.get("melange")
    if not isinstance(melange, dict):
        return [f"{label}: cost_savings artifact has no 'melange' section"]
    models = melange.get("models")
    if not isinstance(models, dict) or not models:
        return [f"{label}: melange section has no per-model results"]
    max_gap = SMOKE_MAX_BO_GAP if doc.get("quick") else MAX_BO_GAP
    for name, row in models.items():
        if not isinstance(row, dict):
            errors.append(f"{label}: melange.models.{name} is not an object")
            continue
        missing = [k for k in MELANGE_MODEL_KEYS if k not in row]
        if missing:
            errors.append(
                f"{label}: melange.models.{name} missing keys {missing}")
            continue
        if float(row["exact_cost"]) <= 0:
            errors.append(
                f"{label}: {name} exact solver cost "
                f"{row['exact_cost']} is not positive")
        if not row["bo_feasible"]:
            errors.append(f"{label}: {name} BO found no feasible pool")
            continue
        gap = float(row["bo_gap"])
        if gap > max_gap:
            errors.append(
                f"{label}: {name} bo_gap {gap:.3f} exceeds the allowed "
                f"{max_gap:.2f} above the exact optimum — BO stopped "
                "converging")
        if gap < MIN_BO_GAP:
            errors.append(
                f"{label}: {name} bo_gap {gap:.3f} is below {MIN_BO_GAP} — "
                "BO undercut the throughput lower bound, the solver or "
                "throughput table is broken")
    return errors


def check_tiers(doc, label: str) -> list[str]:
    """Economics + robustness gates on the hybrid capacity-tier section
    (``payload["tiers"]`` of a scenarios artifact, absent on legacy
    artifacts): every spot-market episode must recover on the hybrid pool;
    the hybrid portfolio must be *strictly cheaper* than every single-tier
    baseline that matches its QoS within the artifact's recorded tolerance
    (vacuously true if no baseline qualifies — then the hybrid pool is the
    only portfolio meeting QoS at all); the matched-scoring carried run
    must report at least the idle-restart run's violation mass under the
    storm; and the seeded tiered composite fuzz must have recovered on
    every sampled timeline (>= 20 seeds on a full run)."""
    tiers = doc.get("tiers")
    if not isinstance(tiers, dict):
        return []
    errors = []
    qos_tol = float(tiers.get("qos_tol", 0.01))
    episodes = tiers.get("episodes")
    if not isinstance(episodes, dict) or not episodes:
        return [f"{label}: tiers section has no 'episodes'"]
    single = tiers.get("single_tier")
    single = single if isinstance(single, dict) else {}
    matched = tiers.get("matched_scoring")
    matched = matched if isinstance(matched, dict) else {}
    idle = tiers.get("idle_baselines")
    idle = idle if isinstance(idle, dict) else {}
    for name, ep in episodes.items():
        if not isinstance(ep, dict):
            errors.append(f"{label}: tier episode {name!r} is not an object")
            continue
        if not ep.get("recovered_all_events", False):
            errors.append(
                f"{label}: tier episode {name!r} did not recover QoS to "
                "target on the hybrid pool",
            )
        hybrid_qos = float(ep.get("qos_rate", 0.0))
        hybrid_cost = float(ep.get("total_cost", 0.0))
        for tier, base in (single.get(name) or {}).items():
            if not isinstance(base, dict):
                continue
            if float(base.get("qos_rate", 0.0)) < hybrid_qos - qos_tol:
                continue       # baseline misses QoS — no economics claim
            if not hybrid_cost < float(base.get("total_cost", 0.0)):
                errors.append(
                    f"{label}: tier episode {name!r}: hybrid portfolio "
                    f"costs {hybrid_cost:.4f}, not cheaper than the "
                    f"QoS-matching {tier}-only baseline "
                    f"({float(base.get('total_cost', 0.0)):.4f})",
                )
        m, i = matched.get(name), idle.get(name)
        if isinstance(m, dict) and isinstance(i, dict):
            mv = m.get("violation_windows")
            iv = i.get("violation_windows")
            if (isinstance(mv, (int, float)) and isinstance(iv, (int, float))
                    and mv < iv):
                errors.append(
                    f"{label}: tier episode {name!r} reports {mv} violation "
                    f"windows under the carried-state clock, fewer than its "
                    f"idle-restart baseline ({iv}) — storm backlog "
                    f"accounting went missing",
                )
    fuzz = tiers.get("fuzz")
    if not isinstance(fuzz, dict):
        errors.append(f"{label}: tiers section has no 'fuzz' sweep")
        return errors
    full = float(doc.get("n_per_phase") or 0) >= 800
    min_seeds = 20 if full else 1
    if float(fuzz.get("n_seeds") or 0) < min_seeds:
        errors.append(
            f"{label}: tiered composite fuzz ran {fuzz.get('n_seeds')} "
            f"seeds, fewer than the required {min_seeds}",
        )
    if not fuzz.get("all_recovered", False):
        bad = [s.get("seed") for s in fuzz.get("per_seed", [])
               if isinstance(s, dict)
               and not s.get("recovered_all_events", False)]
        errors.append(
            f"{label}: tiered composite fuzz failed to recover on "
            f"seed(s) {bad}",
        )
    return errors


# ---------------------------------------------------------------- history
# Trend metrics per bench kind: name -> (value, direction), direction
# "higher" or "lower" meaning which way is better.  Only these named
# metrics participate in the >20% regression warning.
REGRESSION_FRAC = 0.20


def trend_metrics(doc) -> dict[str, tuple[float, str]]:
    bench = doc.get("bench")
    out: dict[str, tuple[float, str]] = {}
    if bench == "batch_eval":
        for row in doc.get("results", []):
            if row.get("batch_size") == 32 and "speedup" in row:
                out["b32_speedup"] = (float(row["speedup"]), "higher")
        grid = doc.get("grid")
        if isinstance(grid, dict) and "speedup" in grid:
            out["grid_speedup"] = (float(grid["speedup"]), "higher")
        warm = doc.get("warm")
        if isinstance(warm, dict) and "speedup" in warm:
            out["warm_speedup"] = (float(warm["speedup"]), "higher")
        routing = doc.get("routing")
        if isinstance(routing, dict):
            if "speedup" in routing:
                out["routing_speedup"] = (float(routing["speedup"]),
                                          "higher")
            if "routed_min_cost" in routing:
                out["routed_min_cost"] = (float(routing["routed_min_cost"]),
                                          "lower")
        tel = doc.get("telemetry")
        if isinstance(tel, dict) and "overhead" in tel:
            out["telemetry_overhead"] = (float(tel["overhead"]), "lower")
    elif bench == "scenarios":
        for name, ep in (doc.get("episodes") or {}).items():
            if isinstance(ep, dict) and "qos_rate" in ep:
                out[f"{name}.qos_rate"] = (float(ep["qos_rate"]), "higher")
            if isinstance(ep, dict) and "total_cost" in ep:
                out[f"{name}.total_cost"] = (float(ep["total_cost"]), "lower")
        tiers = doc.get("tiers")
        tiers = tiers if isinstance(tiers, dict) else {}
        for name, ep in (tiers.get("episodes") or {}).items():
            if isinstance(ep, dict) and "qos_rate" in ep:
                out[f"tiers.{name}.qos_rate"] = (float(ep["qos_rate"]),
                                                 "higher")
            if isinstance(ep, dict) and "total_cost" in ep:
                out[f"tiers.{name}.total_cost"] = (float(ep["total_cost"]),
                                                   "lower")
    elif bench == "stream":
        stream = doc.get("stream")
        if isinstance(stream, dict) and "qps" in stream:
            out["stream_qps"] = (float(stream["qps"]), "higher")
        memory = doc.get("memory")
        if isinstance(memory, dict) and "ratio" in memory:
            out["stream_mem_ratio"] = (float(memory["ratio"]), "lower")
        day = doc.get("day")
        if isinstance(day, dict) and "qos_rate" in day:
            out["day.qos_rate"] = (float(day["qos_rate"]), "higher")
    elif bench == "cost_savings":
        melange = doc.get("melange")
        melange = melange if isinstance(melange, dict) else {}
        for name, row in (melange.get("models") or {}).items():
            if isinstance(row, dict) and row.get("bo_feasible"):
                out[f"{name}.bo_gap"] = (float(row["bo_gap"]), "lower")
    return out


def git_commit() -> str:
    try:
        proc = subprocess.run(
            ["git", "rev-parse", "--short", "HEAD"],
            capture_output=True,
            text=True,
            timeout=10,
            cwd=REPO_ROOT,
        )
    except OSError:
        return "unknown"
    return proc.stdout.strip() or "unknown"


def update_history(doc, label: str, history_path: Path, commit: str) -> list[str]:
    """Upsert this artifact's trend metrics into the history log (keyed by
    (commit, bench, source) — re-running on the same commit replaces the
    prior row instead of appending a duplicate); return WARN strings for
    >20% regressions vs the most recent entry for the same (bench, source)
    from a *different* commit — the committed root baseline and a fresh
    bench_out measurement trend independently."""
    metrics = trend_metrics(doc)
    warnings = []
    entries = []
    last = None
    if history_path.exists():
        for line in history_path.read_text().splitlines():
            try:
                entry = json.loads(line)
            except json.JSONDecodeError:
                continue
            if (entry.get("commit") == commit
                    and entry.get("bench") == doc.get("bench")
                    and entry.get("source") == label):
                continue       # superseded by this run's row (upsert)
            entries.append(entry)
            if entry.get("bench") == doc.get("bench") and entry.get("source") == label:
                last = entry
    if last is not None:
        for name, (value, direction) in metrics.items():
            prev = last.get("metrics", {}).get(name)
            prev_value = prev[0] if isinstance(prev, list) else prev
            if not isinstance(prev_value, (int, float)) or prev_value == 0:
                continue
            change = (value - prev_value) / abs(prev_value)
            if direction == "higher":
                regressed = change < -REGRESSION_FRAC
            else:
                regressed = change > REGRESSION_FRAC
            if regressed:
                warnings.append(
                    f"{label}: {name} regressed "
                    f"{100 * abs(change):.1f}% vs commit "
                    f"{last.get('commit', '?')} "
                    f"({prev_value:.4g} -> {value:.4g})",
                )
    record = {
        "commit": commit,
        "bench": doc.get("bench"),
        "source": label,
        "metrics": {k: [v, d] for k, (v, d) in metrics.items()},
    }
    entries.append(record)
    history_path.parent.mkdir(exist_ok=True)
    with history_path.open("w") as fh:
        for entry in entries:
            fh.write(json.dumps(entry) + "\n")
    return warnings


def default_paths(bench_dir: Path) -> list[Path]:
    paths = []
    root_baseline = REPO_ROOT / "BENCH_batch_eval.json"
    if root_baseline.exists():
        paths.append(root_baseline)
    if bench_dir.is_dir():
        paths.extend(sorted(bench_dir.glob("BENCH_*.json")))
    return paths


def main(argv=None) -> int:
    parser = argparse.ArgumentParser(description=__doc__)
    parser.add_argument(
        "paths",
        nargs="*",
        type=Path,
        help="artifacts to check (default: repo-root baseline + bench_out)",
    )
    parser.add_argument(
        "--schema-only",
        action="store_true",
        help="validate schemas only; skip hardware-dependent thresholds",
    )
    parser.add_argument(
        "--bench-dir",
        type=Path,
        default=REPO_ROOT / "bench_out",
        help="directory scanned for BENCH_*.json in default mode",
    )
    parser.add_argument(
        "--history",
        action="store_true",
        help="append artifacts to history.jsonl (by commit); warn on regressions",
    )
    parser.add_argument(
        "--history-file",
        type=Path,
        default=None,
        help="history log location (default: <bench-dir>/history.jsonl)",
    )
    args = parser.parse_args(argv)

    paths = list(args.paths) or default_paths(args.bench_dir)
    if not paths:
        print(
            "check_bench: no artifacts found — run "
            "`PYTHONPATH=src python -m benchmarks.bench_batch_eval` first",
        )
        return 1

    # --schema-only short-circuits history entirely: an artifact-only
    # validation pass must neither mutate the trend log nor print trend
    # warnings derived from thresholds it was told to skip.
    history_enabled = args.history and not args.schema_only
    history_path = args.history_file or (args.bench_dir / "history.jsonl")
    commit = git_commit() if history_enabled else None

    errors, warnings = [], []
    for path in paths:
        label = str(path)
        if not path.exists():
            errors.append(f"{label}: not found")
            continue
        try:
            doc = json.loads(path.read_text())
        except json.JSONDecodeError as exc:
            errors.append(f"{label}: invalid JSON ({exc})")
            continue
        schema_errors = validate_schema(doc, label)
        errors.extend(schema_errors)
        if schema_errors:
            continue
        if not args.schema_only:
            if doc.get("bench") == "batch_eval":
                errors.extend(check_batch_eval(doc, label))
            elif doc.get("bench") == "scenarios":
                errors.extend(check_scenarios(doc, label))
            elif doc.get("bench") == "stream":
                errors.extend(check_stream(doc, label))
            elif doc.get("bench") == "cost_savings":
                errors.extend(check_cost_savings(doc, label))
        if history_enabled:
            warnings.extend(update_history(doc, label, history_path, commit))

    for warn in warnings:
        print(f"check_bench: WARN — {warn}")
    if errors:
        for err in errors:
            print(f"check_bench: FAIL — {err}")
        return 1
    mode = "schemas" if args.schema_only else "schemas + perf gates"
    if history_enabled:
        mode += f" + history ({history_path})"
    print(f"check_bench: OK — {len(paths)} artifact(s), {mode}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
