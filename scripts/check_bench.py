#!/usr/bin/env python
"""CI gate on the batched evaluation engine's perf baseline.

Reads BENCH_batch_eval.json (the committed artifact of
benchmarks/bench_batch_eval.py, or a path passed as argv[1]) and fails if
batched throughput at B=32 is below 5x the sequential single-config path —
the tentpole guarantee every later scaling PR builds on.

    python scripts/check_bench.py [path/to/BENCH_batch_eval.json]
"""

from __future__ import annotations

import json
import sys
from pathlib import Path

MIN_SPEEDUP_AT_32 = 5.0


def main() -> int:
    default = Path(__file__).resolve().parent.parent / "BENCH_batch_eval.json"
    path = Path(sys.argv[1]) if len(sys.argv) > 1 else default
    if not path.exists():
        print(f"check_bench: {path} not found — run "
              f"`PYTHONPATH=src python -m benchmarks.bench_batch_eval` first")
        return 1
    doc = json.loads(path.read_text())
    if doc.get("schema_version") != 1 or doc.get("bench") != "batch_eval":
        print(f"check_bench: {path} has unexpected schema "
              f"(schema_version={doc.get('schema_version')!r}, "
              f"bench={doc.get('bench')!r})")
        return 1
    by_b = {r["batch_size"]: r for r in doc["results"]}
    if 32 not in by_b:
        print("check_bench: no B=32 measurement in results")
        return 1
    speedup = float(by_b[32]["speedup"])
    if speedup < MIN_SPEEDUP_AT_32:
        print(f"check_bench: FAIL — batched B=32 speedup {speedup:.2f}x "
              f"< required {MIN_SPEEDUP_AT_32:.1f}x")
        return 1
    print(f"check_bench: OK — batched B=32 speedup {speedup:.2f}x "
          f"(>= {MIN_SPEEDUP_AT_32:.1f}x)")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
